//! Minimal property-testing driver (`proptest` is unavailable offline).
//!
//! A property is a closure over a [`SplitMix64`]-backed [`Gen`]; the
//! driver runs it for `cases` seeds and, on failure, re-runs the failing
//! seed with panic output so the case is reproducible by seed alone
//! (no shrinking — generators here are small enough to eyeball).
//!
//! Used by the invariant tests on the stats containers, MSHR, tag array,
//! launch gate, and trace round-trips.

use super::prng::SplitMix64;

/// Value generator handed to each property case.
pub struct Gen {
    rng: SplitMix64,
    /// Seed of this case, for the failure report.
    pub seed: u64,
}

impl Gen {
    /// u64 in `[0, bound)`.
    pub fn below(&mut self, bound: u64) -> u64 {
        self.rng.next_below(bound)
    }

    /// u64 in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.next_range(lo, hi)
    }

    /// usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.rng.next_below(bound as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// f64 in `[0,1)`.
    pub fn unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// A vector of `len` values drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T)
        -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `prop` for `cases` deterministic cases derived from `base_seed`.
/// Panics with the failing seed on the first violated property.
pub fn run_cases(name: &str, base_seed: u64, cases: u64,
                 mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        // decorrelate case seeds
        let seed = SplitMix64::new(base_seed ^ case).next_u64();
        let mut g = Gen { rng: SplitMix64::new(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut g),
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>()
                    .map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed \
                 {seed:#018x}): {msg}"
            );
        }
    }
}

/// Default case count, overridable via `STREAMSIM_PROPTEST_CASES`.
pub fn default_cases() -> u64 {
    std::env::var("STREAMSIM_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_cases("trivial", 1, 32, |g| {
            count += 1;
            assert!(g.below(10) < 10);
        });
        assert_eq!(count, 32);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            run_cases("fails", 2, 16, |g| {
                assert!(g.below(100) < 50, "drew a big one");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("property 'fails' failed"), "{msg}");
        assert!(msg.contains("seed 0x"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut a = Vec::new();
        run_cases("det", 3, 8, |g| a.push(g.u64()));
        let mut b = Vec::new();
        run_cases("det", 3, 8, |g| b.push(g.u64()));
        assert_eq!(a, b);
    }
}
