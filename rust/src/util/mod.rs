//! Offline-friendly utilities.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure (DESIGN.md §7), so the usual ecosystem crates (`rand`,
//! `criterion`, `proptest`) are hand-rolled here at the scale this
//! project needs: a SplitMix64 PRNG, a micro-benchmark harness used by
//! the `cargo bench` targets, and a tiny property-testing driver.

pub mod bench;
pub mod prng;
pub mod proptest_lite;

/// Integer ceiling division.
#[inline]
pub const fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b` (`b > 0`).
#[inline]
pub const fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

/// `true` iff `v` is a power of two (and non-zero).
#[inline]
pub const fn is_pow2(v: u64) -> bool {
    v != 0 && v & (v - 1) == 0
}

/// log2 of a power of two.
#[inline]
pub const fn log2(v: u64) -> u32 {
    v.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_remainder() {
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(35, 128), 128);
        assert_eq!(round_up(128, 128), 128);
        assert_eq!(round_up(129, 128), 256);
    }

    #[test]
    fn pow2_helpers() {
        assert!(is_pow2(1));
        assert!(is_pow2(128));
        assert!(!is_pow2(0));
        assert!(!is_pow2(96));
        assert_eq!(log2(128), 7);
    }
}
