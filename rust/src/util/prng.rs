//! Deterministic SplitMix64 PRNG.
//!
//! Used by workload generators (randomized-but-reproducible traces), the
//! property-test driver, and benches. SplitMix64 passes BigCrush for the
//! bit budget we need and is 3 instructions on the hot path; the `rand`
//! crate is unavailable offline (DESIGN.md §7).

/// SplitMix64 generator. Every stream of values is fully determined by
/// the seed, which keeps traces and property tests reproducible across
/// runs and machines.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub const fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine
        // here (bias < 2^-32 for our bounds).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.next_below(slice.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn next_below_covers_all_values() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.next_below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = SplitMix64::new(3);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
