//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas
//! artifacts from Rust. Python never runs here.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`),
//! produced once by `python/compile/aot.py`:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Serialized protos are *not* used —
//! the image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction
//! ids (DESIGN.md §7).
//!
//! Artifacts are compiled once at load and cached; execution is
//! synchronous on the CPU PJRT client.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Typed host-side tensor passed to / returned from an artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    /// fp16 travels as f32 host-side; converted at the literal boundary.
    F16 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl HostTensor {
    /// Element count implied by dims.
    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    /// Empty tensor?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. }
            | HostTensor::F16 { dims, .. }
            | HostTensor::I32 { dims, .. } => dims,
        }
    }

    /// f32 view of the data (I32 converted).
    pub fn as_f32(&self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } | HostTensor::F16 { data, .. } => {
                data.clone()
            }
            HostTensor::I32 { data, .. } => {
                data.iter().map(|&v| v as f32).collect()
            }
        }
    }

    fn to_literal(&self) -> Result<Literal> {
        let dims_i64: Vec<i64> =
            self.dims().iter().map(|&d| d as i64).collect();
        match self {
            HostTensor::F32 { data, .. } => {
                Ok(Literal::vec1(data).reshape(&dims_i64)?)
            }
            HostTensor::F16 { data, .. } => {
                let f32lit = Literal::vec1(data).reshape(&dims_i64)?;
                Ok(f32lit.convert(ElementType::F16.primitive_type())?)
            }
            HostTensor::I32 { data, .. } => {
                Ok(Literal::vec1(data).reshape(&dims_i64)?)
            }
        }
    }

    fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match lit.ty()? {
            ElementType::F32 => Ok(HostTensor::F32 {
                data: lit.to_vec::<f32>()?,
                dims,
            }),
            ElementType::F16 => {
                let f32lit =
                    lit.convert(ElementType::F32.primitive_type())?;
                Ok(HostTensor::F16 { data: f32lit.to_vec::<f32>()?, dims })
            }
            ElementType::S32 => Ok(HostTensor::I32 {
                data: lit.to_vec::<i32>()?,
                dims,
            }),
            other => bail!("unsupported artifact output type {other:?}"),
        }
    }
}

/// The PJRT runtime holding compiled executables.
pub struct Runtime {
    client: PjRtClient,
    executables: BTreeMap<String, PjRtLoadedExecutable>,
    artifact_dir: Option<PathBuf>,
}

impl Runtime {
    /// CPU PJRT client, no artifacts loaded.
    pub fn new() -> Result<Self> {
        let client =
            PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            executables: BTreeMap::new(),
            artifact_dir: None,
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact under `name`.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every `*.hlo.txt` in `dir`; returns the loaded names.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("artifact dir {}", dir.display()))?;
        let mut paths: Vec<PathBuf> =
            entries.filter_map(|e| Some(e.ok()?.path())).collect();
        paths.sort();
        for p in paths {
            let fname = p.file_name().and_then(|f| f.to_str());
            if let Some(name) =
                fname.and_then(|f| f.strip_suffix(".hlo.txt"))
            {
                self.load_artifact(name, &p)?;
                names.push(name.to_string());
            }
        }
        if names.is_empty() {
            bail!("no *.hlo.txt artifacts in {} — run `make artifacts`",
                  dir.display());
        }
        self.artifact_dir = Some(dir.to_path_buf());
        Ok(names)
    }

    /// Loaded artifact names.
    pub fn names(&self) -> Vec<String> {
        self.executables.keys().cloned().collect()
    }

    /// Whether `name` is loaded.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name`. Every artifact returns a tuple
    /// (`return_tuple=True` at lowering); the members come back as
    /// [`HostTensor`]s.
    pub fn execute(&self, name: &str, inputs: &[HostTensor])
        -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded \
                                      (have: {})",
                                     self.names().join(", ")))?;
        let literals: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<Literal>(&literals)
            .with_context(|| format!("executing '{name}'"))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        let members = out.to_tuple().context("untupling result")?;
        members.iter().map(HostTensor::from_literal).collect()
    }
}

/// Default artifacts directory (crate-relative, for tests/examples).
pub fn default_artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime_with_artifacts() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let mut rt = Runtime::new().expect("PJRT client");
        rt.load_dir(&dir).expect("load artifacts");
        Some(rt)
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime_with_artifacts() else { return };
        for name in ["stream_program_b1", "stream_program_b3",
                     "deepbench_gemm", "deepbench_gemm_mini",
                     "stats_aggregate"] {
            assert!(rt.has(name), "missing artifact {name}");
        }
    }

    #[test]
    fn executes_stream_program_b3() {
        let Some(rt) = runtime_with_artifacts() else { return };
        let n = 1 << 18;
        let x: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let y = vec![1.0f32; n];
        let z = vec![2.0f32; n];
        let a = vec![3.0f32; n];
        let mk = |v: Vec<f32>| HostTensor::F32 { data: v, dims: vec![n] };
        let out = rt
            .execute("stream_program_b3",
                     &[mk(x.clone()), mk(y), mk(z), mk(a)])
            .unwrap();
        assert_eq!(out.len(), 3);
        let yo = out[0].as_f32();
        let zo = out[1].as_f32();
        let ao = out[2].as_f32();
        // y' = 2*(2x + 1); z' = 3x + 2; a' = first half y'+3, rest 6
        for i in [0usize, 1, 1234, n - 1] {
            let xf = (i % 7) as f32;
            assert!((yo[i] - 2.0 * (2.0 * xf + 1.0)).abs() < 1e-5);
            assert!((zo[i] - (3.0 * xf + 2.0)).abs() < 1e-5);
            let want_a = if i < n / 2 { yo[i] + 3.0 } else { 6.0 };
            assert!((ao[i] - want_a).abs() < 1e-5);
        }
    }

    #[test]
    fn executes_gemm_mini_fp16() {
        let Some(rt) = runtime_with_artifacts() else { return };
        let (m, k, n) = (35usize, 512usize, 256usize);
        // a = all 0.5, b = all 2.0 -> c[i][j] = k * 1.0 = 512
        let a = HostTensor::F16 { data: vec![0.5; m * k],
                                  dims: vec![m, k] };
        let b = HostTensor::F16 { data: vec![2.0; k * n],
                                  dims: vec![k, n] };
        let out = rt.execute("deepbench_gemm_mini", &[a, b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims(), &[m, n]);
        let c = out[0].as_f32();
        for v in [c[0], c[m * n / 2], c[m * n - 1]] {
            assert_eq!(v, 512.0, "fp16 gemm of constants must be exact");
        }
    }

    #[test]
    fn executes_stats_aggregate_matches_host() {
        let Some(rt) = runtime_with_artifacts() else { return };
        let n = 16384usize;
        let (s, t, o) = (8usize, 10usize, 6usize);
        let mut rng = crate::util::prng::SplitMix64::new(42);
        let sid: Vec<i32> =
            (0..n).map(|_| rng.next_below(s as u64) as i32).collect();
        let typ: Vec<i32> =
            (0..n).map(|_| rng.next_below(t as u64) as i32).collect();
        let out_: Vec<i32> =
            (0..n).map(|_| rng.next_below(o as u64) as i32).collect();
        let valid: Vec<i32> =
            (0..n).map(|_| rng.next_below(2) as i32).collect();
        let mk = |v: &[i32]| HostTensor::I32 {
            data: v.to_vec(),
            dims: vec![n],
        };
        let out = rt
            .execute("stats_aggregate",
                     &[mk(&sid), mk(&typ), mk(&out_), mk(&valid)])
            .unwrap();
        assert_eq!(out[0].dims(), &[s, t, o]);
        let cube = out[0].as_f32();
        // host-side oracle
        let mut want = vec![0f32; s * t * o];
        for i in 0..n {
            if valid[i] == 1 {
                let idx = (sid[i] as usize * t + typ[i] as usize) * o
                    + out_[i] as usize;
                want[idx] += 1.0;
            }
        }
        assert_eq!(cube, want);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let Some(rt) = runtime_with_artifacts() else { return };
        assert!(rt.execute("nonexistent", &[]).is_err());
    }
}
