//! SIMT core timing model.
//!
//! Each core hosts up to `max_tbs_per_core` thread blocks; each warp
//! replays its trace ops in order. Memory instructions are coalesced
//! into sector transactions ([`crate::core::coalesce`]) that flow
//! through the L1D (unless `.cg`-bypassed) and on to the interconnect.
//! Loads block their warp until every sector returns (latency tolerance
//! comes from multithreading across warps, as on real SMs); stores are
//! fire-and-forget.
//!
//! Every L1 access records a per-stream stat — the L1 side of the
//! paper's `Total_core_cache_stats_breakdown` — through a
//! [`CoreSink`]: on the parallel path this core's worker thread owns a
//! [`crate::stats::CoreStatShard`] exclusively and the main thread
//! merges it at kernel exit in fixed core-id order; in clean mode the
//! increment goes through [`StatsEngine::inc_core`] so the same-cycle
//! guard sees arrival order. The stream slot carried by each TB was
//! interned once at kernel launch, so the whole path is array
//! indexing.

use std::collections::VecDeque;

use crate::activity::Activity;
use crate::cache::access::{AccessOutcome, AccessType};
use crate::cache::Cache;
use crate::config::SimConfig;
use crate::core::coalesce::coalesce_sectors;
use crate::mem::fetch::{FetchIdAlloc, MemFetch, ReturnPath};
use crate::mem::icnt::DelayQueue;
use crate::stats::{CoreSink, StatsEngine};
use crate::trace::{MemInstr, MemSpace, TbTrace, TraceOp};
use crate::{Cycle, KernelUid, StreamId, StreamSlot};

/// One resident warp.
#[derive(Debug)]
struct WarpCtx {
    ops: VecDeque<TraceOp>,
    /// Pipeline-busy until this cycle (ALU batches).
    busy_until: Cycle,
    /// Outstanding load sectors for the current (blocking) instruction.
    pending_loads: u32,
}

impl WarpCtx {
    fn finished(&self) -> bool {
        self.ops.is_empty() && self.pending_loads == 0
    }

    fn ready(&self, now: Cycle) -> bool {
        !self.ops.is_empty() && self.pending_loads == 0
            && self.busy_until <= now
    }
}

/// One resident thread block.
#[derive(Debug)]
struct ResidentTb {
    kernel_uid: KernelUid,
    stream_id: StreamId,
    /// Interned slot of `stream_id` (assigned at kernel launch).
    stream_slot: StreamSlot,
    tb_index: usize,
    warps: Vec<WarpCtx>,
}

impl ResidentTb {
    fn finished(&self) -> bool {
        self.warps.iter().all(|w| w.finished())
    }
}

/// A finished TB notification: which kernel's TB retired, plus the
/// core/warp footprint the retirement released — the credit the
/// dispatch free-slot ledger ([`crate::sim::dispatch`]) applies at the
/// absorb point instead of re-scanning every core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FinishedTb {
    pub kernel_uid: KernelUid,
    pub tb_index: usize,
    /// Core the TB retired from.
    pub core: u32,
    /// Warps the retirement freed on that core.
    pub warps: u32,
}

/// One SIMT core (SM).
#[derive(Debug)]
pub struct SimtCore {
    pub id: u32,
    slots: Vec<Option<ResidentTb>>,
    l1: Option<Cache>,
    issue_width: u32,
    alu_latency: u32,
    max_warps: u32,
    /// Coalesced transactions awaiting L1/interconnect issue.
    ldst_queue: VecDeque<MemFetch>,
    /// L1 hits serving out their latency.
    hit_queue: DelayQueue<MemFetch>,
    /// Outbound to the interconnect (drained by the top level).
    to_icnt: Vec<MemFetch>,
    /// Retired TBs (drained by the top level).
    finished: Vec<FinishedTb>,
    /// Reused buffer for L1 fill responses (no per-fill allocation).
    fill_scratch: Vec<MemFetch>,
    /// Round-robin scheduler cursor.
    rr: usize,
    /// Cached resident-warp count (kept in sync by accept/retire).
    resident: u32,
    /// Flattened (slot, warp) list for the scheduler; rebuilt lazily
    /// when residency changes instead of every cycle.
    warp_refs: Vec<(usize, usize)>,
    warp_refs_dirty: bool,
}

impl SimtCore {
    /// Build core `id` from the config.
    pub fn new(id: u32, cfg: &SimConfig) -> Self {
        Self {
            id,
            slots: (0..cfg.max_tbs_per_core).map(|_| None).collect(),
            l1: cfg
                .l1d
                .as_ref()
                .map(|c| Cache::new(format!("L1D{id}"), c.clone())),
            issue_width: cfg.issue_width,
            alu_latency: cfg.alu_latency,
            max_warps: cfg.max_warps_per_core,
            ldst_queue: VecDeque::new(),
            hit_queue: DelayQueue::new(cfg.l1_latency),
            to_icnt: Vec::new(),
            finished: Vec::new(),
            fill_scratch: Vec::new(),
            rr: 0,
            resident: 0,
            warp_refs: Vec::new(),
            warp_refs_dirty: true,
        }
    }

    /// Warps currently resident.
    pub fn resident_warps(&self) -> u32 {
        self.resident
    }

    /// Whether a TB with `warps` warps can be accepted.
    pub fn can_accept(&self, warps: u32) -> bool {
        self.slots.iter().any(|s| s.is_none())
            && self.resident_warps() + warps <= self.max_warps
    }

    /// Place a TB on this core. `stream_slot` is the launch-time
    /// interned slot of `stream_id`. Panics if `can_accept` was false.
    pub fn accept_tb(&mut self, kernel_uid: KernelUid,
                     stream_id: StreamId, stream_slot: StreamSlot,
                     tb_index: usize, trace: &TbTrace) {
        let slot = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .expect("accept_tb without free slot");
        self.resident += trace.warps.len() as u32;
        self.warp_refs_dirty = true;
        self.slots[slot] = Some(ResidentTb {
            kernel_uid,
            stream_id,
            stream_slot,
            tb_index,
            warps: trace
                .warps
                .iter()
                .map(|ops| WarpCtx {
                    ops: ops.iter().copied().collect(),
                    busy_until: 0,
                    pending_loads: 0,
                })
                .collect(),
        });
    }

    /// Advance one cycle with central stat admission (the clean-mode /
    /// legacy sequential path). Equivalent to
    /// [`SimtCore::cycle_with`] with [`CoreSink::Central`].
    pub fn cycle(&mut self, now: Cycle, engine: &mut StatsEngine,
                 ids: &mut FetchIdAlloc) {
        self.cycle_with(now, &mut CoreSink::Central(engine), ids);
    }

    /// Advance one cycle. L1 stats land in `sink` keyed by each fetch's
    /// interned stream slot: a worker-owned [`CoreSink::Shard`] on the
    /// parallel path (this core's thread owns the shard exclusively;
    /// the main thread merges it at kernel exit), or
    /// [`CoreSink::Central`] for clean mode's ordered inc-time guard.
    pub fn cycle_with(&mut self, now: Cycle, sink: &mut CoreSink<'_>,
                      ids: &mut FetchIdAlloc) {
        // fast path: nothing resident and nothing in flight
        if self.resident == 0
            && self.ldst_queue.is_empty()
            && self.hit_queue.is_empty()
        {
            return;
        }
        // 1. L1 hits that served their latency wake their warps.
        while let Some(f) = self.hit_queue.pop_ready(now) {
            self.wake(&f);
        }

        // 2. LDST unit: up to issue_width transactions per cycle.
        self.ldst_cycle(now, sink);

        // 3. Warp issue: up to issue_width ready warps, round-robin.
        self.issue_cycle(now, ids);

        // 4. Retire finished TBs.
        let core_id = self.id;
        for slot in &mut self.slots {
            if slot.as_ref().is_some_and(|tb| tb.finished()) {
                let tb = slot.take().unwrap();
                self.resident -= tb.warps.len() as u32;
                self.warp_refs_dirty = true;
                self.finished.push(FinishedTb {
                    kernel_uid: tb.kernel_uid,
                    tb_index: tb.tb_index,
                    core: core_id,
                    warps: tb.warps.len() as u32,
                });
            }
        }
    }

    fn ldst_cycle(&mut self, now: Cycle, sink: &mut CoreSink<'_>) {
        for _ in 0..self.issue_width {
            let Some(front) = self.ldst_queue.front() else { break };
            // L1 bypass (`.cg`) or no L1: straight to the interconnect.
            if front.l1_bypass || self.l1.is_none() {
                let f = self.ldst_queue.pop_front().unwrap();
                self.to_icnt.push(f);
                continue;
            }
            let l1 = self.l1.as_mut().unwrap();
            let f = *front;
            let res = l1.access(&f, now);
            sink.inc(self.id, f.stream_slot, f.access_type,
                     res.outcome, now);
            if res.outcome == AccessOutcome::ReservationFail {
                sink.inc_fail(self.id, f.stream_slot, f.access_type,
                              res.fail.expect("fail reason"), now);
                break; // structural stall: retry same txn next cycle
            }
            self.ldst_queue.pop_front();
            if res.outcome == AccessOutcome::Hit && f.needs_response() {
                self.hit_queue.push(now, f);
            }
            // drain write-throughs / fill requests
            while let Some(down) = l1.pop_miss() {
                self.to_icnt.push(down);
            }
        }
    }

    fn issue_cycle(&mut self, now: Cycle, ids: &mut FetchIdAlloc) {
        // flatten resident warps for round-robin (rebuilt only when
        // residency changed — the per-cycle allocation was the #1
        // profile entry, see EXPERIMENTS.md §Perf)
        if self.warp_refs_dirty {
            self.warp_refs.clear();
            for (s, slot) in self.slots.iter().enumerate() {
                if let Some(tb) = slot {
                    for w in 0..tb.warps.len() {
                        self.warp_refs.push((s, w));
                    }
                }
            }
            self.warp_refs_dirty = false;
        }
        if self.warp_refs.is_empty() {
            return;
        }
        let n = self.warp_refs.len();
        let mut issued = 0;
        for k in 0..n {
            if issued >= self.issue_width {
                break;
            }
            let (s, w) = self.warp_refs[(self.rr + k) % n];
            let core_id = self.id;
            let alu_latency = self.alu_latency;
            let tb = self.slots[s].as_mut().unwrap();
            let (uid, stream, slot) =
                (tb.kernel_uid, tb.stream_id, tb.stream_slot);
            let warp = &mut tb.warps[w];
            if !warp.ready(now) {
                continue;
            }
            match warp.ops.pop_front().unwrap() {
                TraceOp::Alu { count } => {
                    warp.busy_until =
                        now + (count as u64) * alu_latency as u64;
                }
                TraceOp::Mem(mi) => {
                    warp.busy_until = now + 1;
                    let n = Self::expand_mem(
                        &mi, core_id, s as u32, w as u32, uid, stream,
                        slot, ids, &mut self.ldst_queue);
                    if !mi.is_write {
                        warp.pending_loads += n;
                    }
                }
            }
            issued += 1;
        }
        self.rr = (self.rr + 1) % n;
    }

    /// Coalesce a warp memory instruction into sector fetches, pushed
    /// straight onto the LDST queue (no intermediate per-instruction
    /// vector). Returns how many fetches were produced.
    #[allow(clippy::too_many_arguments)]
    fn expand_mem(mi: &MemInstr, core_id: u32, tb_slot: u32,
                  warp_idx: u32, uid: KernelUid, stream: StreamId,
                  stream_slot: StreamSlot, ids: &mut FetchIdAlloc,
                  out: &mut VecDeque<MemFetch>) -> u32 {
        let access_type = match (mi.space, mi.is_write) {
            (MemSpace::Global, false) => AccessType::GlobalAccR,
            (MemSpace::Global, true) => AccessType::GlobalAccW,
            (MemSpace::Local, false) => AccessType::LocalAccR,
            (MemSpace::Local, true) => AccessType::LocalAccW,
            (MemSpace::Const, _) => AccessType::ConstAccR,
            (MemSpace::Texture, _) => AccessType::TextureAccR,
        };
        let mut n = 0;
        for addr in coalesce_sectors(mi) {
            out.push_back(MemFetch {
                id: ids.next(),
                addr,
                bytes: crate::config::SECTOR_SIZE,
                access_type,
                is_write: mi.is_write,
                stream_id: stream,
                stream_slot,
                kernel_uid: uid,
                l1_bypass: mi.l1_bypass,
                ret: (!mi.is_write).then_some(ReturnPath {
                    core_id,
                    tb_slot,
                    warp_idx,
                }),
            });
            n += 1;
        }
        n
    }

    /// Interconnect delivered a response to this core.
    pub fn receive_response(&mut self, f: MemFetch, now: Cycle) {
        if self.l1.is_some() && !f.l1_bypass {
            let mut scratch = std::mem::take(&mut self.fill_scratch);
            self.l1
                .as_mut()
                .unwrap()
                .fill_into(f.addr, now, &mut scratch);
            for r in scratch.drain(..) {
                self.wake(&r);
            }
            self.fill_scratch = scratch;
        } else {
            self.wake(&f);
        }
    }

    fn wake(&mut self, f: &MemFetch) {
        let Some(ret) = f.ret else { return };
        debug_assert_eq!(ret.core_id, self.id);
        if let Some(tb) = self.slots[ret.tb_slot as usize].as_mut() {
            let w = &mut tb.warps[ret.warp_idx as usize];
            debug_assert!(w.pending_loads > 0, "spurious wake");
            w.pending_loads -= 1;
        }
    }

    /// Outbound fetches for the interconnect.
    pub fn drain_to_icnt(&mut self) -> Vec<MemFetch> {
        std::mem::take(&mut self.to_icnt)
    }

    /// Allocation-free drain: append outbound fetches to `out` (the
    /// top-level reuses one scratch buffer across cores and cycles).
    pub fn drain_to_icnt_into(&mut self, out: &mut Vec<MemFetch>) {
        out.append(&mut self.to_icnt);
    }

    /// Retired TBs since the last call.
    pub fn take_finished(&mut self) -> Vec<FinishedTb> {
        std::mem::take(&mut self.finished)
    }

    /// Warm-session reuse: evict all resident TBs, empty every queue
    /// and reset the L1 — the exact post-construction state (slot
    /// count, latencies and the L1 geometry are config, untouched;
    /// buffer capacities are kept).
    pub fn reset(&mut self) {
        for slot in &mut self.slots {
            *slot = None;
        }
        if let Some(l1) = self.l1.as_mut() {
            l1.reset();
        }
        self.ldst_queue.clear();
        self.hit_queue.clear();
        self.to_icnt.clear();
        self.finished.clear();
        self.fill_scratch.clear();
        self.rr = 0;
        self.resident = 0;
        self.warp_refs.clear();
        self.warp_refs_dirty = true;
    }

    /// Any work left on this core?
    pub fn busy(&self) -> bool {
        self.slots.iter().any(|s| s.is_some())
            || !self.ldst_queue.is_empty()
            || !self.hit_queue.is_empty()
            || !self.to_icnt.is_empty()
            || self.l1.as_ref().is_some_and(|l1| l1.mshr_len() > 0)
    }

    /// Event-horizon lower bound (the fast-forward contract, see
    /// [`crate::activity`]): ticks at `now+1 ..= now + h - 1` are
    /// guaranteed no-ops. Queued LDST transactions, undrained
    /// outbound fetches and unretired TB notifications pin the
    /// horizon to 1; otherwise it is the earliest of the hit-queue
    /// head ready cycle and the soonest `busy_until` among warps
    /// that are neither load-blocked nor finished. Load-blocked
    /// warps (`pending_loads > 0`) contribute nothing: their wake is
    /// a response delivery, and the response is in flight somewhere
    /// whose own horizon (icnt/partition/exchange) bounds the jump.
    /// A fully-finished resident TB pins the horizon to 1 — its
    /// retirement is the next tick's work.
    pub fn next_event_in(&self, now: Cycle) -> Cycle {
        if !self.ldst_queue.is_empty()
            || !self.to_icnt.is_empty()
            || !self.finished.is_empty()
        {
            return 1;
        }
        let mut h = self
            .hit_queue
            .next_ready()
            .map_or(Cycle::MAX, |r| r.saturating_sub(now).max(1));
        for tb in self.slots.iter().flatten() {
            let mut tb_done = true;
            for w in &tb.warps {
                if w.pending_loads > 0 {
                    tb_done = false;
                    continue;
                }
                if w.ops.is_empty() {
                    continue;
                }
                tb_done = false;
                h = h.min(w.busy_until.saturating_sub(now).max(1));
            }
            if tb_done {
                return 1;
            }
        }
        h
    }

    /// Cheap activity summary for the idle-skip active set.
    /// `activity().is_idle()` is exactly `!self.busy()` (every `busy`
    /// term maps to a field; pinned by `tests/activity.rs`), and an
    /// idle core's [`SimtCore::cycle_with`] takes the resident==0 fast
    /// path — a provable no-op.
    pub fn activity(&self) -> Activity {
        Activity {
            resident_warps: self.resident,
            resident_tbs: self.slots.iter()
                .filter(|s| s.is_some()).count() as u32,
            queued: self.ldst_queue.len(),
            pending_fills: self.hit_queue.len(),
            mshr_entries: self.l1.as_ref().map_or(0, |l| l.mshr_len()),
            mshr_waiting: self.l1.as_ref()
                .map_or(0, |l| l.mshr_waiting()),
            outbound: self.to_icnt.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{StatDomain, StatMode};
    use crate::trace::{Dim3, KernelTrace};

    const L1: StatDomain = StatDomain::L1;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::preset("sm7_titanv_mini").unwrap();
        c.issue_width = 2;
        c
    }

    fn mem_op(base: u64, is_write: bool, bypass: bool) -> TraceOp {
        TraceOp::Mem(MemInstr {
            pc: 0,
            space: MemSpace::Global,
            is_write,
            size: 4,
            base_addr: base,
            stride: 4,
            active_mask: u32::MAX,
            l1_bypass: bypass,
        })
    }

    fn one_warp_tb(ops: Vec<TraceOp>) -> TbTrace {
        TbTrace { warps: vec![ops] }
    }

    /// `accept_tb` with the stream interned through the engine, as the
    /// dispatcher does.
    fn accept(core: &mut SimtCore, engine: &mut StatsEngine,
              uid: KernelUid, stream: StreamId, tb_index: usize,
              trace: &TbTrace) {
        let slot = engine.intern_stream(stream);
        core.accept_tb(uid, stream, slot, tb_index, trace);
    }

    /// Cycle the core + echo fetches straight back as responses (a
    /// zero-latency perfect memory) until idle, then flush shards.
    fn run_to_idle(core: &mut SimtCore, engine: &mut StatsEngine)
        -> Cycle {
        let mut ids = FetchIdAlloc::default();
        let mut now = 0;
        while core.busy() && now < 100_000 {
            core.cycle(now, engine, &mut ids);
            for f in core.drain_to_icnt() {
                if f.needs_response() || (!f.is_write) {
                    core.receive_response(f, now);
                }
            }
            now += 1;
        }
        assert!(now < 100_000, "core deadlocked");
        engine.flush_shards();
        now
    }

    #[test]
    fn tb_lifecycle_and_retire() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        assert!(core.can_accept(1));
        accept(&mut core, &mut e, 1, 5, 0, &one_warp_tb(vec![
            TraceOp::Alu { count: 3 },
            mem_op(0x1000, false, false),
        ]));
        assert_eq!(core.resident_warps(), 1);
        run_to_idle(&mut core, &mut e);
        assert_eq!(core.take_finished(), vec![FinishedTb {
            kernel_uid: 1, tb_index: 0, core: 0, warps: 1 }]);
        assert_eq!(core.resident_warps(), 0);
        assert!(core.activity().is_idle());
    }

    #[test]
    fn coalesced_load_counts_4_sector_accesses() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        accept(&mut core, &mut e, 1, 5, 0,
               &one_warp_tb(vec![mem_op(0x1000, false, false)]));
        run_to_idle(&mut core, &mut e);
        let table = e.cache(L1).stream_table(5).unwrap();
        assert_eq!(table.total_for_type(AccessType::GlobalAccR), 4);
    }

    #[test]
    fn cg_load_bypasses_l1_entirely() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        accept(&mut core, &mut e, 1, 5, 0,
               &one_warp_tb(vec![mem_op(0x1000, false, true)]));
        let mut ids = FetchIdAlloc::default();
        let mut now = 0;
        let mut bypassed = Vec::new();
        while core.busy() && now < 10_000 {
            core.cycle(now, &mut e, &mut ids);
            for f in core.drain_to_icnt() {
                assert!(f.l1_bypass);
                bypassed.push(f);
                core.receive_response(f, now);
            }
            now += 1;
        }
        assert_eq!(bypassed.len(), 4);
        // no L1 stats recorded at all
        e.flush_shards();
        assert!(e.cache(L1).streams().is_empty());
    }

    #[test]
    fn store_is_fire_and_forget_write_through() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        accept(&mut core, &mut e, 1, 5, 0,
               &one_warp_tb(vec![mem_op(0x2000, true, false)]));
        let mut ids = FetchIdAlloc::default();
        let mut down_writes = 0;
        let mut now = 0;
        while core.busy() && now < 10_000 {
            core.cycle(now, &mut e, &mut ids);
            for f in core.drain_to_icnt() {
                assert!(f.is_write);
                down_writes += 1;
            }
            now += 1;
        }
        // 4 sectors written through
        assert_eq!(down_writes, 4);
        e.flush_shards();
        assert_eq!(e.cache(L1).stream_table(5).unwrap()
                    .total_for_type(AccessType::GlobalAccW), 4);
        // TB retired without any response
        assert_eq!(core.take_finished(), vec![FinishedTb {
            kernel_uid: 1, tb_index: 0, core: 0, warps: 1 }]);
    }

    #[test]
    fn l1_hit_after_fill() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        // two identical loads: first misses, second hits in L1
        accept(&mut core, &mut e, 1, 5, 0, &one_warp_tb(vec![
            mem_op(0x1000, false, false),
            mem_op(0x1000, false, false),
        ]));
        run_to_idle(&mut core, &mut e);
        let t = e.cache(L1).stream_table(5).unwrap();
        // first load: 1 line MISS + 3 SECTOR_MISSes; second load: 4 HITs
        assert_eq!(t.get(AccessType::GlobalAccR, AccessOutcome::Miss), 1);
        assert_eq!(t.get(AccessType::GlobalAccR,
                         AccessOutcome::SectorMiss), 3);
        assert_eq!(t.get(AccessType::GlobalAccR, AccessOutcome::Hit), 4);
    }

    #[test]
    fn two_tbs_from_different_streams_attribute_separately() {
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        accept(&mut core, &mut e, 1, 10, 0,
               &one_warp_tb(vec![mem_op(0x1000, false, false)]));
        accept(&mut core, &mut e, 2, 20, 0,
               &one_warp_tb(vec![mem_op(0x8000, false, false)]));
        run_to_idle(&mut core, &mut e);
        assert_eq!(e.cache(L1).stream_table(10).unwrap()
                    .total_for_type(AccessType::GlobalAccR), 4);
        assert_eq!(e.cache(L1).stream_table(20).unwrap()
                    .total_for_type(AccessType::GlobalAccR), 4);
    }

    #[test]
    fn capacity_limits_respected() {
        let mut c = cfg();
        c.max_tbs_per_core = 2;
        c.max_warps_per_core = 3;
        let mut core = SimtCore::new(0, &c);
        let mut e = StatsEngine::new(StatMode::PerStream);
        accept(&mut core, &mut e, 1, 0, 0, &TbTrace {
            warps: vec![vec![TraceOp::Alu { count: 1 }]; 2],
        });
        assert!(core.can_accept(1));
        assert!(!core.can_accept(2)); // warp limit
        accept(&mut core, &mut e, 1, 0, 1, &one_warp_tb(vec![]));
        assert!(!core.can_accept(1)); // slot limit
    }

    #[test]
    fn kernel_trace_smoke_through_core() {
        // run a small real KernelTrace shape end-to-end
        let k = KernelTrace {
            name: "mini".into(),
            kernel_id: 1,
            grid: Dim3::linear(3),
            block: Dim3::linear(64),
            stream_id: 2,
            shared_mem_bytes: 0,
            tbs: (0..3)
                .map(|tb| TbTrace {
                    warps: (0..2)
                        .map(|w| vec![
                            mem_op(0x10_0000 + tb * 0x100 + w * 0x80,
                                   false, false),
                            TraceOp::Alu { count: 2 },
                            mem_op(0x20_0000 + tb * 0x100 + w * 0x80,
                                   true, false),
                        ])
                        .collect(),
                })
                .collect(),
        };
        k.validate().unwrap();
        let mut core = SimtCore::new(0, &cfg());
        let mut e = StatsEngine::new(StatMode::PerStream);
        let mut ids = FetchIdAlloc::default();
        let mut now = 0;
        let mut pending: Vec<usize> = (0..3).collect();
        let mut done = 0;
        // run past TB retirement until the LDST queue drains (stores are
        // fire-and-forget and may outlive their TB)
        while (done < 3 || core.busy()) && now < 100_000 {
            if let Some(tb) = pending.first().copied() {
                if core.can_accept(2) {
                    accept(&mut core, &mut e, 1, 2, tb, &k.tbs[tb]);
                    pending.remove(0);
                }
            }
            core.cycle(now, &mut e, &mut ids);
            for f in core.drain_to_icnt() {
                if !f.is_write {
                    core.receive_response(f, now);
                }
            }
            done += core.take_finished().len();
            now += 1;
        }
        assert_eq!(done, 3);
        e.flush_shards();
        let t = e.cache(L1).stream_table(2).unwrap();
        // 3 TBs x 2 warps x 4 sectors reads + same writes
        assert_eq!(t.total_for_type(AccessType::GlobalAccR), 24);
        assert_eq!(t.total_for_type(AccessType::GlobalAccW), 24);
    }
}
