//! SIMT core model: warp scheduling, coalescing, L1 access.
//!
//! * [`coalesce`] — warp instruction → sector transactions.
//! * [`simt_core`] — the per-SM timing model with resident TBs.

pub mod coalesce;
pub mod simt_core;

pub use coalesce::coalesce_sectors;
pub use simt_core::{FinishedTb, SimtCore};
