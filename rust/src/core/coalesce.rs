//! Memory coalescer: warp instruction → sector transactions.
//!
//! GPGPU-Sim's `memory_coalescing_arch` merges the 32 lanes' addresses
//! into the minimal set of 32-byte sector transactions (for sectored
//! caches). Each unique touched sector becomes one [`MemFetch`]-sized
//! access; fully-coalesced fp32 warps therefore produce 4 sector
//! accesses per 128 B line, matching GPGPU-Sim's counted accesses.

use crate::config::cache_cfg::SECTOR_SIZE;
use crate::trace::MemInstr;

/// Unique sector-aligned addresses touched by a warp instruction,
/// ascending. Each lane covers `[addr, addr + size)` and may straddle a
/// sector boundary.
pub fn coalesce_sectors(mi: &MemInstr) -> Vec<u64> {
    let mut sectors: Vec<u64> = Vec::with_capacity(8);
    for lane_addr in mi.lane_addrs() {
        let first = lane_addr & !(SECTOR_SIZE as u64 - 1);
        let last = (lane_addr + mi.size as u64 - 1)
            & !(SECTOR_SIZE as u64 - 1);
        let mut s = first;
        loop {
            sectors.push(s);
            if s >= last {
                break;
            }
            s += SECTOR_SIZE as u64;
        }
    }
    sectors.sort_unstable();
    sectors.dedup();
    sectors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::MemSpace;

    fn mi(base: u64, stride: i64, mask: u32, size: u8) -> MemInstr {
        MemInstr {
            pc: 0,
            space: MemSpace::Global,
            is_write: false,
            size,
            base_addr: base,
            stride,
            active_mask: mask,
            l1_bypass: false,
        }
    }

    #[test]
    fn fully_coalesced_fp32_warp_is_4_sectors() {
        // 32 lanes x 4B consecutive = 128B = 4 sectors
        let s = coalesce_sectors(&mi(0x1000, 4, u32::MAX, 4));
        assert_eq!(s, vec![0x1000, 0x1020, 0x1040, 0x1060]);
    }

    #[test]
    fn single_lane_single_sector() {
        let s = coalesce_sectors(&mi(0x1008, 0, 1, 8));
        assert_eq!(s, vec![0x1000]);
    }

    #[test]
    fn same_address_all_lanes_coalesces_to_one() {
        let s = coalesce_sectors(&mi(0x2000, 0, u32::MAX, 4));
        assert_eq!(s, vec![0x2000]);
    }

    #[test]
    fn strided_access_explodes() {
        // stride 128: every lane a different line -> 32 sectors
        let s = coalesce_sectors(&mi(0x0, 128, u32::MAX, 4));
        assert_eq!(s.len(), 32);
        assert_eq!(s[1] - s[0], 128);
    }

    #[test]
    fn lane_straddling_sector_boundary_takes_two() {
        // one lane, 8B at 0x101C crosses into 0x1020
        let s = coalesce_sectors(&mi(0x101C, 0, 1, 8));
        assert_eq!(s, vec![0x1000, 0x1020]);
    }

    #[test]
    fn unaligned_warp_takes_extra_sector() {
        // 32 x 4B starting at 0x1010: spans 0x1010..0x1090 -> 5 sectors
        let s = coalesce_sectors(&mi(0x1010, 4, u32::MAX, 4));
        assert_eq!(s.len(), 5);
        assert_eq!(s[0], 0x1000);
        assert_eq!(*s.last().unwrap(), 0x1080);
    }

    #[test]
    fn partial_mask_covers_only_active_lanes() {
        // lanes 0..16 of fp32: 64B -> 2 sectors
        let s = coalesce_sectors(&mi(0x1000, 4, 0x0000_FFFF, 4));
        assert_eq!(s, vec![0x1000, 0x1020]);
    }

    #[test]
    fn property_sector_count_bounds() {
        use crate::util::proptest_lite::{default_cases, run_cases};
        run_cases("coalesce-bounds", 0xC0A1, default_cases(), |g| {
            let m = mi(
                g.below(1 << 20) * 4,
                [0i64, 4, 8, 32, 128][g.index(5)],
                g.u64() as u32,
                [4u8, 8][g.index(2)],
            );
            let s = coalesce_sectors(&m);
            let lanes = m.active_lanes() as usize;
            // each lane touches at most 2 sectors; dedup only shrinks
            assert!(s.len() <= lanes * 2);
            if lanes > 0 {
                assert!(!s.is_empty());
            } else {
                assert!(s.is_empty());
            }
            // sorted unique
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            // all sector-aligned
            assert!(s.iter().all(|a| a % SECTOR_SIZE as u64 == 0));
        });
    }
}
