//! Figure data — the `graph.py` replacement.
//!
//! The paper's Figs. 2–5 plot, for every `(access_type, outcome)`
//! combination with non-zero counts, three bar groups: `tip_serialized`
//! (blue), `clean` (orange), and per-stream `tip` bars (green). We emit
//! the same series as an aligned text table + CSV, with the per-stream
//! tip bars and their sum next to the clean aggregate.

use std::fmt::Write as _;

use crate::cache::access::{AccessOutcome, AccessType};
use crate::stats::engine::{CacheView, StatsEngine};
use crate::StreamId;

use super::ThreeWay;

/// One plotted row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FigureRow {
    pub cache: &'static str,
    pub access_type: AccessType,
    pub outcome: AccessOutcome,
    pub serialized: u64,
    pub clean: u64,
    /// (stream, count) green bars.
    pub tip_per_stream: Vec<(StreamId, u64)>,
}

impl FigureRow {
    /// Σ of the green bars.
    pub fn tip_sum(&self) -> u64 {
        self.tip_per_stream.iter().map(|(_, c)| c).sum()
    }
}

/// A figure's full data (both cache levels + the timelines).
#[derive(Debug, Clone)]
pub struct FigureData {
    pub title: String,
    pub rows: Vec<FigureRow>,
    pub tip_gantt: String,
    pub serialized_gantt: String,
}

/// Collect the rows for one cache level.
fn rows_for(cache: &'static str, tip: CacheView<'_>,
            clean: CacheView<'_>, serialized: CacheView<'_>)
    -> Vec<FigureRow> {
    let streams: Vec<StreamId> = tip
        .streams()
        .into_iter()
        .filter(|s| *s != StatsEngine::AGG_KEY)
        .collect();
    let tip_total = tip.total_table();
    let clean_total = clean.total_table();
    let ser_total = serialized.total_table();
    let mut rows = Vec::new();
    for t in AccessType::ALL {
        for o in AccessOutcome::ALL {
            let any = tip_total.get(t, o) != 0
                || clean_total.get(t, o) != 0
                || ser_total.get(t, o) != 0;
            if !any {
                continue;
            }
            rows.push(FigureRow {
                cache,
                access_type: t,
                outcome: o,
                serialized: ser_total.get(t, o),
                clean: clean_total.get(t, o),
                tip_per_stream: streams
                    .iter()
                    .map(|s| (*s, tip.get(*s, t, o)))
                    .collect(),
            });
        }
    }
    rows
}

/// Build a [`FigureData`] from a three-way run.
pub fn build(title: &str, tw: &ThreeWay) -> FigureData {
    let mut rows = rows_for("L1", tw.tip.stats.l1(),
                            tw.clean.stats.l1(),
                            tw.tip_serialized.stats.l1());
    rows.extend(rows_for("L2", tw.tip.stats.l2(), tw.clean.stats.l2(),
                         tw.tip_serialized.stats.l2()));
    FigureData {
        title: title.to_string(),
        rows,
        tip_gantt: tw.tip.gantt.clone(),
        serialized_gantt: tw.tip_serialized.gantt.clone(),
    }
}

impl FigureData {
    /// Aligned text table (what EXPERIMENTS.md embeds).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let streams: Vec<StreamId> = self
            .rows
            .first()
            .map(|r| r.tip_per_stream.iter().map(|(s, _)| *s).collect())
            .unwrap_or_default();
        let _ = write!(out, "{:<4} {:<14} {:<17} {:>12} {:>12} {:>12}",
                       "lvl", "access_type", "outcome", "serialized",
                       "clean", "tip_sum");
        for s in &streams {
            let _ = write!(out, " {:>9}", format!("tip_s{s}"));
        }
        let _ = writeln!(out);
        for r in &self.rows {
            let _ = write!(out,
                           "{:<4} {:<14} {:<17} {:>12} {:>12} {:>12}",
                           r.cache, r.access_type.name(),
                           r.outcome.name(), r.serialized, r.clean,
                           r.tip_sum());
            for (_, c) in &r.tip_per_stream {
                let _ = write!(out, " {c:>9}");
            }
            let _ = writeln!(out);
        }
        let _ = writeln!(out, "\n-- timeline (tip, concurrent) --\n{}",
                         self.tip_gantt);
        let _ = writeln!(out, "-- timeline (tip_serialized) --\n{}",
                         self.serialized_gantt);
        out
    }

    /// CSV export (`figure.csv` artifact per experiment).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "cache,access_type,outcome,config,stream,count\n");
        for r in &self.rows {
            let _ = writeln!(out, "{},{},{},tip_serialized,all,{}",
                             r.cache, r.access_type.name(),
                             r.outcome.name(), r.serialized);
            let _ = writeln!(out, "{},{},{},clean,all,{}", r.cache,
                             r.access_type.name(), r.outcome.name(),
                             r.clean);
            for (s, c) in &r.tip_per_stream {
                let _ = writeln!(out, "{},{},{},tip,{s},{c}", r.cache,
                                 r.access_type.name(), r.outcome.name());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::config::SimConfig;
    use crate::harness::run_three_configs;
    use crate::workloads;

    #[test]
    fn figure_table_renders_l2_lat() {
        let g = workloads::generate("l2_lat").unwrap();
        let cfg = SimConfig::preset("minimal").unwrap();
        let tw = run_three_configs(&cfg, &g).unwrap();
        let fig = tw.figure("Figure 2: l2_lat_4stream");
        let table = fig.render_table();
        assert!(table.contains("GLOBAL_ACC_R"));
        assert!(table.contains("tip_s1"));
        assert!(table.contains("timeline (tip, concurrent)"));
        // all four stream columns present
        for s in 1..=4 {
            assert!(table.contains(&format!("tip_s{s}")), "{table}");
        }
    }

    #[test]
    fn rows_expose_green_equals_orange_for_symmetric_workload() {
        let g = workloads::generate("l2_lat").unwrap();
        let cfg = SimConfig::preset("minimal").unwrap();
        let tw = run_three_configs(&cfg, &g).unwrap();
        let fig = tw.figure("fig2");
        // Fig. 2's headline: green (tip per-stream sums) == orange
        // (clean) for every row of this symmetric workload
        for r in fig.rows.iter().filter(|r| r.cache == "L2") {
            assert_eq!(r.tip_sum(), r.clean,
                       "row {:?}/{:?}", r.access_type, r.outcome);
        }
    }
}
