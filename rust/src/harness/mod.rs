//! Validation harness — runs a workload under the paper's three
//! configurations and produces the per-figure comparison series.
//!
//! Configurations (paper §5.1):
//! * `tip` — per-stream stats, concurrent kernels (the contribution);
//! * `clean` — flat stats incl. the same-cycle under-count, concurrent;
//! * `tip_serialized` — per-stream stats with the `busy_streams.size()
//!   == 0` launch gate.
//!
//! The checks encode the claims behind Figs. 2–5:
//! * `Σ_streams tip == exact aggregate` (and `== clean` when no
//!   collisions occurred);
//! * `tip ≥ clean` cell-wise (under-counting);
//! * serialized `HIT` ≥ concurrent `HIT` with the deficit appearing as
//!   `MSHR_HIT` (shared-array workloads);
//! * serialized timelines have zero cross-stream overlap, concurrent
//!   ones don't.

pub mod figure;

use anyhow::{Context, Result};

use crate::api::{SimBuilder, Snapshot};
use crate::cache::access::{AccessOutcome, AccessType};
use crate::config::SimConfig;
use crate::stats::{StatDomain, StatMode, StatTable};
use crate::workloads::GeneratedWorkload;

pub use figure::FigureData;

/// One simulation's outcome under a label. `stats` is a final
/// [`Snapshot`] — every read below goes through the facade's typed
/// views, never through simulator internals.
#[derive(Debug)]
pub struct RunResult {
    pub label: String,
    pub stats: Snapshot,
    pub timeline_csv: String,
    pub gantt: String,
}

/// The three-config bundle.
#[derive(Debug)]
pub struct ThreeWay {
    pub tip: RunResult,
    pub clean: RunResult,
    pub tip_serialized: RunResult,
    /// Loss-free aggregate oracle (not in the paper's plots; used for
    /// the Σ check).
    pub exact: RunResult,
    /// Whether the base config modeled an L1D (L1 checks apply).
    pub has_l1: bool,
}

fn run_one(label: &str, base: &SimConfig, mode: StatMode,
           serialized: bool, g: &GeneratedWorkload) -> Result<RunResult> {
    let mut session = SimBuilder::from_config(base.clone())
        .stat_mode(mode)
        .serialize_streams(serialized)
        .label(label)
        .build()
        .with_context(|| format!("building config '{label}'"))?;
    // enqueue by reference — no per-config deep copy of the trace
    session
        .enqueue(&g.workload)
        .with_context(|| format!("enqueueing '{label}'"))?;
    session
        .run_to_idle()
        .with_context(|| format!("running config '{label}'"))?;
    // the session is finished — move the stats out, don't clone them
    let stats = session.into_snapshot();
    let gantt = stats.render_timeline(72);
    let timeline_csv = crate::timeline::to_csv(stats.kernel_times());
    Ok(RunResult { label: label.into(), stats, timeline_csv, gantt })
}

/// Run the paper's three configs (plus the exact oracle).
pub fn run_three_configs(base: &SimConfig, g: &GeneratedWorkload)
    -> Result<ThreeWay> {
    Ok(ThreeWay {
        tip: run_one("tip", base, StatMode::PerStream, false, g)?,
        clean: run_one("clean", base, StatMode::AggregateBuggy, false,
                       g)?,
        tip_serialized: run_one("tip_serialized", base,
                                StatMode::PerStream, true, g)?,
        exact: run_one("exact", base, StatMode::AggregateExact, false,
                       g)?,
        has_l1: base.l1d.is_some(),
    })
}

/// Validation verdict for one claim.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Check {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

impl ThreeWay {
    /// Run every validation check for this workload.
    pub fn validate(&self, g: &GeneratedWorkload) -> Vec<Check> {
        let mut checks = Vec::new();
        let mut push = |name: &str, passed: bool, detail: String| {
            checks.push(Check { name: name.into(), passed, detail });
        };

        // 1. Σ_streams tip == exact aggregate (L1 and L2)
        let tip_l2 = self.tip.stats.l2().total_table();
        let exact_l2 = self.exact.stats.l2().total_table();
        push("sum_tip_equals_exact_l2", tip_l2 == exact_l2,
             format!("tip Σ={} exact={}", tip_l2.total(),
                     exact_l2.total()));
        let tip_l1 = self.tip.stats.l1().total_table();
        let exact_l1 = self.exact.stats.l1().total_table();
        push("sum_tip_equals_exact_l1", tip_l1 == exact_l1,
             format!("tip Σ={} exact={}", tip_l1.total(),
                     exact_l1.total()));

        // 1b. the same Σ-invariant in the engine's extension domains
        // (DRAM, interconnect, power) — the unified-engine guarantee
        for d in [StatDomain::Dram, StatDomain::Icnt, StatDomain::Power] {
            let tip_total = self.tip.stats.domain_total(d);
            let exact_total = self.exact.stats.domain_total(d);
            push(&format!("sum_tip_equals_exact_{}", d.name()),
                 tip_total == exact_total,
                 format!("tip Σ={tip_total} exact={exact_total}"));
        }

        // 1c. no memory response was ever dropped for lack of a
        // return path (read from the unified loss report)
        let dropped_resp = self.tip.stats.losses().dropped_responses;
        push("no_dropped_responses", dropped_resp == 0,
             format!("dropped={dropped_resp}"));

        // 2. tip >= clean cell-wise (under-count)
        let clean_l2 = self.clean.stats.l2().total_table();
        push("tip_dominates_clean_l2", tip_l2.dominates(&clean_l2),
             format!("tip Σ={} clean Σ={} (dropped={})",
                     tip_l2.total(), clean_l2.total(),
                     self.clean.stats.l2().dropped()));
        let clean_l1 = self.clean.stats.l1().total_table();
        push("tip_dominates_clean_l1", tip_l1.dominates(&clean_l1),
             format!("tip Σ={} clean Σ={} (dropped={})",
                     tip_l1.total(), clean_l1.total(),
                     self.clean.stats.l1().dropped()));

        // 3. serviced accesses conserved across launch gatings — only
        // guaranteed when the generator declares its L2 traffic
        // gating-independent (no cross-kernel L1/L2 reuse; DESIGN.md
        // §4). For reuse-heavy workloads (DeepBench) the L2 access mix
        // legitimately changes with interleaving.
        let serviced = |t: &StatTable| {
            AccessOutcome::ALL
                .iter()
                .filter(|o| o.is_serviced())
                .map(|o| t.total_for_outcome(*o))
                .sum::<u64>()
        };
        let ser_l2 = self.tip_serialized.stats.l2().total_table();
        if g.expected.deterministic_l2_traffic {
            push("serviced_conserved_l2",
                 serviced(&tip_l2) == serviced(&ser_l2),
                 format!("tip={} serialized={}", serviced(&tip_l2),
                         serviced(&ser_l2)));
        }

        // 4. serialized HITs >= concurrent HITs with the deficit as
        // MSHR_HIT (paper Fig. 2) — claimed only for small shared
        // working sets that fit in L2; for L2-exceeding footprints
        // concurrency *improves* hit rates instead.
        if g.expected.check_hit_shift {
            let hit_conc = tip_l2.total_for_outcome(AccessOutcome::Hit);
            let hit_ser = ser_l2.total_for_outcome(AccessOutcome::Hit);
            let mshr_conc =
                tip_l2.total_for_outcome(AccessOutcome::MshrHit);
            let mshr_ser =
                ser_l2.total_for_outcome(AccessOutcome::MshrHit);
            push("serialized_hits_ge_concurrent",
                 hit_ser >= hit_conc,
                 format!("HIT ser={hit_ser} conc={hit_conc}; MSHR_HIT \
                          ser={mshr_ser} conc={mshr_conc}"));
            push("concurrent_mshr_hits_present", mshr_conc >= mshr_ser,
                 format!("MSHR_HIT conc={mshr_conc} ser={mshr_ser}"));
        }

        // 5. timeline: concurrent overlaps, serialized doesn't
        let conc_overlap =
            self.tip.stats.kernel_times().cross_stream_overlaps();
        let ser_overlap = self
            .tip_serialized
            .stats
            .kernel_times()
            .cross_stream_overlaps();
        let multi_stream = g.workload.streams().len() > 1;
        push("serialized_never_overlaps", ser_overlap == 0,
             format!("serialized overlaps={ser_overlap}"));
        if multi_stream {
            push("concurrent_overlaps", conc_overlap > 0,
                 format!("concurrent overlaps={conc_overlap}"));
        }

        // 6. analytic expectations (where the generator guarantees
        // them). Counts are over *serviced* outcomes — RESERVATION_FAIL
        // replays are structural retries, not accesses. L1 checks only
        // apply when the config has an L1 at all.
        if self.has_l1 {
            for (stream, want) in &g.expected.l1_reads {
                let got = self.tip.stats.l1().stream_table(*stream)
                    .map_or(0, |t| t.total_serviced_for_type(
                        AccessType::GlobalAccR));
                push(&format!("l1_reads_stream{stream}"), got == *want,
                     format!("got={got} want={want}"));
            }
            for (stream, want) in &g.expected.l1_writes {
                let got = self.tip.stats.l1().stream_table(*stream)
                    .map_or(0, |t| t.total_serviced_for_type(
                        AccessType::GlobalAccW));
                push(&format!("l1_writes_stream{stream}"), got == *want,
                     format!("got={got} want={want}"));
            }
        }
        for (stream, want) in &g.expected.l2_reads {
            let got = self.tip.stats.l2().stream_table(*stream)
                .map_or(0, |t| t.total_serviced_for_type(
                    AccessType::GlobalAccR));
            push(&format!("l2_reads_stream{stream}"), got == *want,
                 format!("got={got} want={want}"));
        }
        for (stream, want) in &g.expected.l2_writes {
            let got = self.tip.stats.l2().stream_table(*stream)
                .map_or(0, |t| t.total_serviced_for_type(
                    AccessType::GlobalAccW));
            push(&format!("l2_writes_stream{stream}"), got == *want,
                 format!("got={got} want={want}"));
        }
        checks
    }

    /// Render the per-figure comparison (see [`figure`]).
    pub fn figure(&self, title: &str) -> FigureData {
        figure::build(title, self)
    }
}

/// Convenience: all checks passed?
pub fn all_passed(checks: &[Check]) -> bool {
    checks.iter().all(|c| c.passed)
}

/// Render checks as an aligned report.
pub fn render_checks(checks: &[Check]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for c in checks {
        let _ = writeln!(out, "  [{}] {:<36} {}",
                         if c.passed { "PASS" } else { "FAIL" },
                         c.name, c.detail);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn l2_lat_three_way_validates() {
        let g = workloads::generate("l2_lat").unwrap();
        let cfg = SimConfig::preset("minimal").unwrap();
        let tw = run_three_configs(&cfg, &g).unwrap();
        let checks = tw.validate(&g);
        assert!(all_passed(&checks), "\n{}", render_checks(&checks));
    }

    #[test]
    fn mini_stream_bench_three_way_validates() {
        let g = workloads::generate("bench1_mini").unwrap();
        let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        let tw = run_three_configs(&cfg, &g).unwrap();
        let checks = tw.validate(&g);
        assert!(all_passed(&checks), "\n{}", render_checks(&checks));
    }

    #[test]
    fn deepbench_mini_three_way_validates() {
        let g = workloads::generate("deepbench_mini").unwrap();
        let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        let tw = run_three_configs(&cfg, &g).unwrap();
        let checks = tw.validate(&g);
        assert!(all_passed(&checks), "\n{}", render_checks(&checks));
    }
}
