//! Accel-Sim-style configuration system.
//!
//! A [`SimConfig`] is built from (in precedence order) a preset, a
//! `gpgpusim.config`-style file (`-key value` lines, `#` comments), and
//! CLI `-key value` overrides — the same layering Accel-Sim gets from
//! `-config` files plus command-line flags.

pub mod cache_cfg;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use cache_cfg::{
    CacheConfig, CacheKind, ReplacementPolicy, SetIndexFunction,
    WriteAllocatePolicy, WritePolicy, SECTOR_SIZE,
};

use crate::stats::StatMode;

/// Full simulator configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimConfig {
    /// Preset name this config was derived from.
    pub preset: String,

    // ---- execution model -------------------------------------------------
    /// Number of SIMT cores (SMs).
    pub num_cores: u32,
    /// `-gpgpu_concurrent_kernel_sm`: kernels from different streams may
    /// be resident simultaneously (paper §4 step 1 requires 1).
    pub concurrent_kernel_sm: bool,
    /// The paper's §5.1 serialization patch: only launch a kernel when no
    /// stream is busy (`busy_streams.size() == 0`).
    pub serialize_streams: bool,
    /// Stat semantics (tip / clean / exact) — see [`StatMode`].
    pub stat_mode: StatMode,
    /// Worker threads for the parallel core/partition loop
    /// (`--sim-threads`): 0 = available parallelism, 1 = the
    /// sequential path; capped at `num_cores`. Per-stream/exact stats
    /// are bit-identical for every value; clean mode always runs
    /// sequentially (its under-count is an arrival-order artifact).
    pub sim_threads: u32,
    /// Max thread blocks resident per core.
    pub max_tbs_per_core: u32,
    /// Max warps resident per core.
    pub max_warps_per_core: u32,
    /// Warp size (threads).
    pub warp_size: u32,
    /// Warp instructions issued per core per cycle.
    pub issue_width: u32,
    /// Fixed latency (cycles) of a non-memory instruction.
    pub alu_latency: u32,

    // ---- memory system ---------------------------------------------------
    /// L1 data cache geometry (None = no L1D, all global goes to L2).
    pub l1d: Option<CacheConfig>,
    /// L1 hit latency (cycles).
    pub l1_latency: u32,
    /// L2 geometry (per sub-partition slice).
    pub l2: CacheConfig,
    /// Number of L2/memory sub-partitions.
    pub num_l2_partitions: u32,
    /// L2 hit latency (cycles).
    pub l2_latency: u32,
    /// Interconnect one-way latency (cycles).
    pub icnt_latency: u32,
    /// Interconnect per-direction flit bandwidth (fetches/cycle).
    pub icnt_flit_per_cycle: u32,
    /// Sharded double-buffered interconnect exchange (default): the
    /// crossbar runs inside the worker phases and the main thread's
    /// between-barrier work is an O(threads) buffer swap. `0` selects
    /// the central exchange (the PR-2 loop; byte-identical stats,
    /// O(fetches/cycle) serialized routing) — kept as the measured
    /// "before" baseline.
    pub icnt_sharded: bool,
    /// Idle-skip active-set scheduling (default): each worker chunk
    /// keeps dense active-id lists and the core/partition phases tick
    /// only components whose [`crate::activity::Activity`] is
    /// non-idle; wake edges (TB dispatch, inbound exchange delivery)
    /// re-insert sleepers before the cycle that would observe them, so
    /// stats stay byte-identical at every `sim_threads` value. `0`
    /// ticks every component every cycle — kept as the measured
    /// "before" baseline, like `icnt_sharded`.
    pub idle_skip: bool,
    /// Event-horizon fast-forward (default): every tickable component
    /// reports a conservative `next_event_in(now)` lower bound; when
    /// the global minimum horizon is `k > 1` the clock loop advances
    /// by `k` in one step instead of ticking through `k - 1`
    /// provably-quiet cycles. Jumps are clamped at `max_cycles`,
    /// external step ceilings (server `stream` delta boundaries,
    /// cycle budgets) and kernel-exit merge points, so stats stay
    /// byte-identical to the always-tick loop. `0` ticks every cycle
    /// — kept as the measured "before" baseline, like `idle_skip`.
    pub fast_forward: bool,
    /// DRAM access latency on top of L2 miss (cycles).
    pub dram_latency: u32,
    /// DRAM serviced requests per partition per cycle (throughput cap).
    pub dram_per_cycle: u32,

    // ---- observability ---------------------------------------------------
    /// Cycle-stamped event recording ([`crate::obs`]). Off by default
    /// so the byte-compared determinism paths run with zero recording
    /// overhead; `1` attaches a bounded [`crate::obs::Recorder`] to
    /// the clock loop (stats stay byte-identical either way — the
    /// recorder never touches a counter).
    pub obs_enabled: bool,

    // ---- limits ----------------------------------------------------------
    /// Safety valve for runaway simulations.
    pub max_cycles: u64,
    /// Kernel-launch window size (Accel-Sim reads this many trace
    /// commands ahead).
    pub launch_window: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        presets::sm7_titanv_mini()
    }
}

/// All preset names (one source for [`SimConfig::preset`], its error
/// text, and the CLI help surfaces — mirrors `workloads::BENCHES`).
pub const PRESETS: [&str; 3] =
    ["sm7_titanv", "sm7_titanv_mini", "minimal"];

impl SimConfig {
    /// Look up a preset by name.
    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "sm7_titanv" => Ok(presets::sm7_titanv()),
            "sm7_titanv_mini" => Ok(presets::sm7_titanv_mini()),
            "minimal" => Ok(presets::minimal()),
            other => bail!("unknown preset '{other}' (have: {})",
                           PRESETS.join(", ")),
        }
    }

    /// Apply `-key value` overrides (from a config file or the CLI).
    pub fn apply_overrides(&mut self, kv: &BTreeMap<String, String>)
        -> Result<()> {
        for (k, v) in kv {
            self.apply_one(k, v)
                .with_context(|| format!("option '-{k} {v}'"))?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, val: &str) -> Result<()> {
        fn b(v: &str) -> Result<bool> {
            match v {
                "1" | "true" => Ok(true),
                "0" | "false" => Ok(false),
                _ => bail!("expected 0/1, got '{v}'"),
            }
        }
        match key {
            "gpgpu_n_clusters" | "num_cores" => {
                self.num_cores = val.parse()?;
            }
            "gpgpu_concurrent_kernel_sm" | "concurrent_kernel_sm" => {
                self.concurrent_kernel_sm = b(val)?;
            }
            "serialize_streams" => self.serialize_streams = b(val)?,
            "sim_threads" => self.sim_threads = val.parse()?,
            "stat_mode" => {
                self.stat_mode = match val {
                    "tip" | "per_stream" => StatMode::PerStream,
                    "clean" | "aggregate" => StatMode::AggregateBuggy,
                    "exact" => StatMode::AggregateExact,
                    _ => bail!("unknown stat_mode '{val}'"),
                };
            }
            "gpgpu_max_cta_per_core" | "max_tbs_per_core" => {
                self.max_tbs_per_core = val.parse()?;
            }
            "max_warps_per_core" => self.max_warps_per_core = val.parse()?,
            "warp_size" => self.warp_size = val.parse()?,
            "issue_width" => self.issue_width = val.parse()?,
            "alu_latency" => self.alu_latency = val.parse()?,
            "gpgpu_cache:dl1" | "l1d" => {
                self.l1d = if val == "none" {
                    None
                } else {
                    Some(CacheConfig::parse(val)?)
                };
            }
            "l1_latency" => self.l1_latency = val.parse()?,
            "gpgpu_cache:dl2" | "l2" => {
                self.l2 = CacheConfig::parse(val)?;
            }
            "gpgpu_n_mem" | "num_l2_partitions" => {
                self.num_l2_partitions = val.parse()?;
            }
            "l2_latency" => self.l2_latency = val.parse()?,
            "icnt_latency" => self.icnt_latency = val.parse()?,
            "icnt_flit_per_cycle" => {
                self.icnt_flit_per_cycle = val.parse()?;
            }
            "icnt_sharded" => self.icnt_sharded = b(val)?,
            "idle_skip" => self.idle_skip = b(val)?,
            "fast_forward" => self.fast_forward = b(val)?,
            "obs_enabled" => self.obs_enabled = b(val)?,
            "dram_latency" => self.dram_latency = val.parse()?,
            "dram_per_cycle" => self.dram_per_cycle = val.parse()?,
            "max_cycles" => self.max_cycles = val.parse()?,
            "launch_window" => self.launch_window = val.parse()?,
            other => bail!("unknown config option '{other}'"),
        }
        Ok(())
    }

    /// Parse a `gpgpusim.config`-style file into overrides and apply.
    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let kv = parse_config_text(&text)?;
        self.apply_overrides(&kv)
    }

    /// Internal consistency checks.
    pub fn validate(&self) -> Result<()> {
        if self.num_cores == 0 || self.num_l2_partitions == 0 {
            bail!("need at least one core and one partition");
        }
        if self.warp_size == 0 || self.max_warps_per_core == 0 {
            bail!("warp geometry must be non-zero");
        }
        if let Some(l1) = &self.l1d {
            l1.validate()?;
        }
        self.l2.validate()?;
        if self.serialize_streams && self.concurrent_kernel_sm {
            // legal (the paper's tip_serialized config does exactly this)
        }
        Ok(())
    }

    /// Non-fatal configuration advisories as `(kind, message)` pairs —
    /// conditions that are legal but silently change behaviour. The
    /// `kind` is a stable machine-readable tag
    /// (`streamsim::api::ConfigNote` wraps these as typed notes at the
    /// builder boundary; the CLI prints them as `note:` lines).
    ///
    /// Currently:
    /// * `clean_mode_pins_threads` — clean (`aggregate`) stat mode
    ///   requires inc-time arrival order, so an explicit
    ///   `sim_threads > 1` request is pinned to 1 worker instead of
    ///   honoured. (The previously *silent* pin — now surfaced.)
    pub fn validation_warnings(&self) -> Vec<(&'static str, String)> {
        let mut warnings = Vec::new();
        if self.stat_mode == StatMode::AggregateBuggy
            && self.sim_threads > 1
        {
            warnings.push((
                "clean_mode_pins_threads",
                format!(
                    "clean (aggregate) stat mode needs inc-time \
                     arrival order for its same-cycle guard; \
                     sim_threads={} will be pinned to 1 worker",
                    self.sim_threads),
            ));
        }
        warnings
    }

    /// Human-readable summary printed at simulation start.
    pub fn summary(&self) -> String {
        format!(
            "preset={} cores={} l2_parts={} concurrent_kernel_sm={} \
             serialize_streams={} stat_mode={} sim_threads={} icnt={} \
             idle_skip={} fast_forward={} l1d={} l2_capacity={}KiB",
            self.preset,
            self.num_cores,
            self.num_l2_partitions,
            self.concurrent_kernel_sm as u8,
            self.serialize_streams as u8,
            self.stat_mode.label(),
            if self.sim_threads == 0 {
                "auto".to_string()
            } else {
                self.sim_threads.to_string()
            },
            if self.icnt_sharded { "sharded" } else { "central" },
            self.idle_skip as u8,
            self.fast_forward as u8,
            self.l1d.as_ref().map_or("none".into(),
                |c| format!("{}KiB", c.capacity() / 1024)),
            self.l2.capacity() * self.num_l2_partitions as u64 / 1024,
        )
    }
}

/// Parse `-key value` lines (Accel-Sim style); `#` starts a comment;
/// bare `key value` (no dash) and `key = value` are also accepted.
pub fn parse_config_text(text: &str) -> Result<BTreeMap<String, String>> {
    let mut kv = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let line = line.strip_prefix('-').unwrap_or(line);
        let (k, v) = if let Some((k, v)) = line.split_once('=') {
            (k.trim(), v.trim())
        } else if let Some((k, v)) = line.split_once(char::is_whitespace) {
            (k.trim(), v.trim())
        } else {
            bail!("config line {}: '{raw}' has no value", lineno + 1);
        };
        if k.is_empty() || v.is_empty() {
            bail!("config line {}: empty key or value", lineno + 1);
        }
        kv.insert(k.to_string(), v.to_string());
    }
    Ok(kv)
}

/// Built-in configuration presets.
pub mod presets {
    use super::*;

    /// TITAN V (SM7) — the paper's validation target: 80 SMs, sectored
    /// 128 KiB L1D per SM, 4.5 MiB L2 in 24 partitions.
    pub fn sm7_titanv() -> SimConfig {
        SimConfig {
            preset: "sm7_titanv".into(),
            num_cores: 80,
            concurrent_kernel_sm: true,
            serialize_streams: false,
            stat_mode: StatMode::PerStream,
            sim_threads: 0,
            max_tbs_per_core: 32,
            max_warps_per_core: 64,
            warp_size: 32,
            issue_width: 4,
            alu_latency: 4,
            l1d: Some(
                CacheConfig::parse("S:4:128:64,L:L:m:N:L,A:512:8,8:0,32")
                    .unwrap()),
            l1_latency: 28,
            // 24 partitions x 64 sets x 24 ways x 128 B = 4.5 MiB;
            // lazy-fetch-on-read write allocate, as the real TITAN V
            // config (`..,L:B:m:L:P,..`) — required for the paper's
            // §5.1 HIT/MSHR_HIT behaviour
            l2: CacheConfig::parse("S:64:128:24,L:B:m:L:L,A:192:4,32:0,32")
                .unwrap(),
            num_l2_partitions: 24,
            l2_latency: 180,
            icnt_latency: 8,
            icnt_flit_per_cycle: 32,
            icnt_sharded: true,
            idle_skip: true,
            fast_forward: true,
            obs_enabled: false,
            dram_latency: 160,
            dram_per_cycle: 2,
            max_cycles: 200_000_000,
            launch_window: 16,
        }
    }

    /// Scaled-down TITAN V for unit/integration tests: same policies and
    /// stat semantics, 4 SMs, small caches so microbenchmarks exercise
    /// misses and MSHR merging quickly.
    pub fn sm7_titanv_mini() -> SimConfig {
        let mut c = sm7_titanv();
        c.preset = "sm7_titanv_mini".into();
        c.num_cores = 4;
        c.max_tbs_per_core = 8;
        c.max_warps_per_core = 32; // fits 1024-thread TBs (bench3)
        c.l1d = Some(
            CacheConfig::parse("S:4:128:8,L:L:m:N:L,A:64:8,8:0,32")
                .unwrap());
        c.l2 = CacheConfig::parse("S:16:128:8,L:B:m:L:L,A:64:4,16:0,32")
            .unwrap();
        c.num_l2_partitions = 4;
        c.l2_latency = 60;
        c.dram_latency = 60;
        c.max_cycles = 20_000_000;
        c
    }

    /// Smallest functional config (1 core, 1 partition, tiny L2) for
    /// deterministic hand-counted tests like the Fig. 2 microbenchmark.
    pub fn minimal() -> SimConfig {
        let mut c = sm7_titanv_mini();
        c.preset = "minimal".into();
        c.num_cores = 1;
        c.num_l2_partitions = 1;
        c.l1d = None;
        c.l2 = CacheConfig::parse("S:4:128:4,L:B:m:L:L,A:16:4,8:0,32")
            .unwrap();
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in PRESETS {
            let c = SimConfig::preset(name).unwrap();
            c.validate().unwrap();
            assert_eq!(c.preset, name);
        }
        assert!(SimConfig::preset("nope").is_err());
    }

    #[test]
    fn parse_config_text_formats() {
        let text = "\
# a comment
-gpgpu_concurrent_kernel_sm 1
num_cores = 8
l2_latency 99   # trailing comment
";
        let kv = parse_config_text(text).unwrap();
        assert_eq!(kv["gpgpu_concurrent_kernel_sm"], "1");
        assert_eq!(kv["num_cores"], "8");
        assert_eq!(kv["l2_latency"], "99");
    }

    #[test]
    fn overrides_apply() {
        let mut c = SimConfig::default();
        let kv = parse_config_text(
            "-gpgpu_concurrent_kernel_sm 0\n-stat_mode clean\n\
             -num_cores 2\n-sim_threads 4\n").unwrap();
        c.apply_overrides(&kv).unwrap();
        assert!(!c.concurrent_kernel_sm);
        assert_eq!(c.stat_mode, StatMode::AggregateBuggy);
        assert_eq!(c.num_cores, 2);
        assert_eq!(c.sim_threads, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = SimConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("bogus_option".to_string(), "1".to_string());
        assert!(c.apply_overrides(&kv).is_err());
    }

    #[test]
    fn cache_override_roundtrip() {
        let mut c = SimConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("gpgpu_cache:dl1".to_string(), "none".to_string());
        c.apply_overrides(&kv).unwrap();
        assert!(c.l1d.is_none());
        kv.insert("gpgpu_cache:dl1".to_string(),
                  "S:4:128:64,L:L:m:N:L,A:512:8,8:0,32".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.l1d.as_ref().unwrap().assoc, 64);
    }

    #[test]
    fn apply_file_roundtrip() {
        let dir = std::env::temp_dir().join("streamsim_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.config");
        std::fs::write(&path,
            "-gpgpu_n_clusters 3\n-stat_mode exact\n").unwrap();
        let mut c = SimConfig::default();
        c.apply_file(&path).unwrap();
        assert_eq!(c.num_cores, 3);
        assert_eq!(c.stat_mode, StatMode::AggregateExact);
    }

    #[test]
    fn summary_mentions_key_fields() {
        let s = SimConfig::preset("sm7_titanv").unwrap().summary();
        assert!(s.contains("cores=80"));
        assert!(s.contains("stat_mode=tip"));
        assert!(s.contains("icnt=sharded"));
    }

    #[test]
    fn icnt_sharded_knob_defaults_on_and_overrides() {
        for name in PRESETS {
            assert!(SimConfig::preset(name).unwrap().icnt_sharded,
                    "{name}: sharded exchange must be the default");
        }
        let mut c = SimConfig::default();
        let kv = parse_config_text("-icnt_sharded 0\n").unwrap();
        c.apply_overrides(&kv).unwrap();
        assert!(!c.icnt_sharded);
        assert!(c.summary().contains("icnt=central"));
    }

    #[test]
    fn idle_skip_knob_defaults_on_and_overrides() {
        for name in PRESETS {
            assert!(SimConfig::preset(name).unwrap().idle_skip,
                    "{name}: idle-skip scheduling must be the default");
        }
        let mut c = SimConfig::default();
        assert!(c.summary().contains("idle_skip=1"));
        let kv = parse_config_text("-idle_skip 0\n").unwrap();
        c.apply_overrides(&kv).unwrap();
        assert!(!c.idle_skip);
        assert!(c.summary().contains("idle_skip=0"));
        assert!(c.apply_overrides(&parse_config_text(
            "-idle_skip maybe\n").unwrap()).is_err());
    }

    #[test]
    fn fast_forward_knob_defaults_on_and_overrides() {
        for name in PRESETS {
            assert!(SimConfig::preset(name).unwrap().fast_forward,
                    "{name}: event-horizon jumps must be the default");
        }
        let mut c = SimConfig::default();
        assert!(c.summary().contains("fast_forward=1"));
        let kv = parse_config_text("-fast_forward 0\n").unwrap();
        c.apply_overrides(&kv).unwrap();
        assert!(!c.fast_forward);
        assert!(c.summary().contains("fast_forward=0"));
        assert!(c.apply_overrides(&parse_config_text(
            "-fast_forward maybe\n").unwrap()).is_err());
    }

    #[test]
    fn obs_knob_defaults_off_and_overrides() {
        for name in PRESETS {
            assert!(!SimConfig::preset(name).unwrap().obs_enabled,
                    "{name}: event recording must default off");
        }
        let mut c = SimConfig::default();
        let kv = parse_config_text("-obs_enabled 1\n").unwrap();
        c.apply_overrides(&kv).unwrap();
        assert!(c.obs_enabled);
        assert!(c.apply_overrides(&parse_config_text(
            "-obs_enabled maybe\n").unwrap()).is_err());
    }

    #[test]
    fn clean_mode_thread_pin_is_warned_not_silent() {
        let mut c = SimConfig::preset("sm7_titanv_mini").unwrap();
        // default (tip, auto threads): no advisories
        assert!(c.validation_warnings().is_empty());
        // clean + auto threads: the user didn't ask for parallelism —
        // still quiet
        c.stat_mode = StatMode::AggregateBuggy;
        c.sim_threads = 0;
        assert!(c.validation_warnings().is_empty());
        c.sim_threads = 1;
        assert!(c.validation_warnings().is_empty());
        // clean + an explicit parallel request: surfaced, typed
        c.sim_threads = 8;
        let w = c.validation_warnings();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].0, "clean_mode_pins_threads");
        assert!(w[0].1.contains("sim_threads=8"));
        assert!(w[0].1.contains("pinned to 1"));
        // and it is a warning, not an error
        c.validate().unwrap();
    }
}
