//! GPGPU-Sim cache-geometry string parser.
//!
//! Accel-Sim configs describe each cache with a compact string, e.g. the
//! TITAN V L1D `S:4:128:64,L:L:m:N:L,A:512:8,8:0,32` — this module parses
//! the subset of that grammar the simulator models:
//!
//! ```text
//! <ct>:<nsets>:<line>:<assoc>,<repl>:<wr>:<alloc>:<wralloc>:<six>,
//! <mshr>:<entries>:<merge>,<miss_queue>:<result_fifo>,<data_port>
//! ```
//!
//! * `ct` — `N` normal or `S` sectored (4×32 B sectors per 128 B line)
//! * `repl` — `L` LRU / `F` FIFO
//! * `wr` — `L` local-WB/global-WT / `B` write-back / `T` write-through
//! * `alloc` — `m` on-miss / `f` on-fill / `s` stream-fetch
//! * `wralloc` — `N` no-write-allocate / `W` write-allocate /
//!   `L` lazy-fetch-on-read
//! * `six` — set-index function: `L` linear / `P` (h)polynomial /
//!   `X` bitwise-xor (we model L and X; P falls back to X)

use anyhow::{bail, Context, Result};

use crate::util::is_pow2;

/// Sectored or normal line organisation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheKind {
    /// Whole-line fills.
    Normal,
    /// 32-byte sector fills within the line.
    Sectored,
}

/// Replacement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplacementPolicy {
    Lru,
    Fifo,
}

/// Write-hit policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritePolicy {
    /// Write-back (L2).
    WriteBack,
    /// Write-through (L1 global).
    WriteThrough,
    /// GPGPU-Sim `L`: local write-back, global write-through — for our
    /// workloads (global only) this behaves as write-through.
    LocalWbGlobalWt,
}

/// Write-miss policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteAllocatePolicy {
    /// Write miss does not allocate (forwarded to the next level).
    NoWriteAllocate,
    /// Write miss allocates the line (fetch-on-write).
    WriteAllocate,
    /// GPGPU-Sim `L`: lazy fetch on read (allocate, fill sectors on
    /// demand). Modeled as allocate-without-fetch.
    LazyFetchOnRead,
}

/// Set-index hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetIndexFunction {
    /// Plain modulo.
    Linear,
    /// XOR-fold of higher address bits (decorrelates power-of-two
    /// strides; stands in for GPGPU-Sim's `P`/`H` hashes as well).
    BitwiseXor,
}

/// Parsed cache geometry + policies.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    pub kind: CacheKind,
    pub nsets: u32,
    pub line_size: u32,
    pub assoc: u32,
    pub replacement: ReplacementPolicy,
    pub write_policy: WritePolicy,
    pub write_allocate: WriteAllocatePolicy,
    pub set_index: SetIndexFunction,
    pub mshr_entries: u32,
    pub mshr_max_merge: u32,
    pub miss_queue_size: u32,
    pub result_fifo_size: u32,
    pub data_port_width: u32,
}

/// Fixed GPU sector size (bytes), as in GPGPU-Sim.
pub const SECTOR_SIZE: u32 = 32;

impl CacheConfig {
    /// Parse an Accel-Sim cache-geometry string.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(',').collect();
        if parts.len() != 5 {
            bail!("cache config '{s}': want 5 comma groups, got {}",
                  parts.len());
        }
        let geo: Vec<&str> = parts[0].split(':').collect();
        if geo.len() != 4 {
            bail!("cache config '{s}': geometry group needs \
                   ct:nsets:line:assoc");
        }
        let kind = match geo[0] {
            "N" => CacheKind::Normal,
            "S" => CacheKind::Sectored,
            other => bail!("unknown cache type '{other}'"),
        };
        let nsets: u32 = geo[1].parse().context("nsets")?;
        let line_size: u32 = geo[2].parse().context("line size")?;
        let assoc: u32 = geo[3].parse().context("assoc")?;

        let pol: Vec<&str> = parts[1].split(':').collect();
        if pol.len() != 5 {
            bail!("cache config '{s}': policy group needs 5 fields");
        }
        let replacement = match pol[0] {
            "L" => ReplacementPolicy::Lru,
            "F" => ReplacementPolicy::Fifo,
            other => bail!("unknown replacement '{other}'"),
        };
        let write_policy = match pol[1] {
            "B" => WritePolicy::WriteBack,
            "T" => WritePolicy::WriteThrough,
            "L" => WritePolicy::LocalWbGlobalWt,
            other => bail!("unknown write policy '{other}'"),
        };
        // pol[2] (alloc on miss/fill) does not change stat semantics at
        // our fidelity; accepted and ignored.
        let write_allocate = match pol[3] {
            "N" => WriteAllocatePolicy::NoWriteAllocate,
            "W" => WriteAllocatePolicy::WriteAllocate,
            "L" => WriteAllocatePolicy::LazyFetchOnRead,
            other => bail!("unknown write-allocate '{other}'"),
        };
        let set_index = match pol[4] {
            "L" => SetIndexFunction::Linear,
            "X" | "P" | "H" => SetIndexFunction::BitwiseXor,
            other => bail!("unknown set-index fn '{other}'"),
        };

        let mshr: Vec<&str> = parts[2].split(':').collect();
        if mshr.len() != 3 {
            bail!("cache config '{s}': mshr group needs type:entries:merge");
        }
        // mshr[0] type (A/B/S) — assoc table either way at our fidelity.
        let mshr_entries: u32 = mshr[1].parse().context("mshr entries")?;
        let mshr_max_merge: u32 = mshr[2].parse().context("mshr merge")?;

        let mq: Vec<&str> = parts[3].split(':').collect();
        if mq.len() != 2 {
            bail!("cache config '{s}': queue group needs mq:result_fifo");
        }
        let miss_queue_size: u32 = mq[0].parse().context("miss queue")?;
        let result_fifo_size: u32 = mq[1].parse().context("result fifo")?;
        let data_port_width: u32 = parts[4].parse().context("data port")?;

        let cfg = Self {
            kind,
            nsets,
            line_size,
            assoc,
            replacement,
            write_policy,
            write_allocate,
            set_index,
            mshr_entries,
            mshr_max_merge,
            miss_queue_size,
            result_fifo_size,
            data_port_width,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks shared by parse and programmatic construction.
    pub fn validate(&self) -> Result<()> {
        if !is_pow2(self.nsets as u64) {
            bail!("nsets {} not a power of two", self.nsets);
        }
        if !is_pow2(self.line_size as u64) || self.line_size < SECTOR_SIZE {
            bail!("line size {} invalid", self.line_size);
        }
        if self.assoc == 0 || self.mshr_entries == 0
            || self.miss_queue_size == 0 {
            bail!("zero-sized structural resource");
        }
        Ok(())
    }

    /// Total data capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.nsets as u64 * self.assoc as u64 * self.line_size as u64
    }

    /// Sectors per line (1 for normal caches).
    pub fn sectors_per_line(&self) -> u32 {
        match self.kind {
            CacheKind::Normal => 1,
            CacheKind::Sectored => self.line_size / SECTOR_SIZE,
        }
    }

    /// Block (line) address of `addr`.
    #[inline]
    pub fn block_addr(&self, addr: u64) -> u64 {
        addr & !(self.line_size as u64 - 1)
    }

    /// Sector index of `addr` within its line.
    #[inline]
    pub fn sector_of(&self, addr: u64) -> u32 {
        match self.kind {
            CacheKind::Normal => 0,
            CacheKind::Sectored => {
                ((addr & (self.line_size as u64 - 1)) / SECTOR_SIZE as u64)
                    as u32
            }
        }
    }

    /// Set index of `addr`.
    #[inline]
    pub fn set_of(&self, addr: u64) -> u32 {
        let block = addr >> self.line_size.trailing_zeros();
        let mask = self.nsets as u64 - 1;
        match self.set_index {
            SetIndexFunction::Linear => (block & mask) as u32,
            SetIndexFunction::BitwiseXor => {
                let upper = block >> self.nsets.trailing_zeros();
                ((block ^ upper) & mask) as u32
            }
        }
    }

    /// Tag of `addr` (full block address, as GPGPU-Sim does — tags are
    /// compared on block addresses so set-hash collisions stay distinct).
    #[inline]
    pub fn tag_of(&self, addr: u64) -> u64 {
        self.block_addr(addr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // TITAN V-like L1D and L2 strings used by the presets.
    const L1: &str = "S:4:128:64,L:L:m:N:L,A:512:8,8:0,32";
    const L2: &str = "S:32:128:24,L:B:m:W:L,A:192:4,32:0,32";

    #[test]
    fn parses_l1_string() {
        let c = CacheConfig::parse(L1).unwrap();
        assert_eq!(c.kind, CacheKind::Sectored);
        assert_eq!(c.nsets, 4);
        assert_eq!(c.line_size, 128);
        assert_eq!(c.assoc, 64);
        assert_eq!(c.write_policy, WritePolicy::LocalWbGlobalWt);
        assert_eq!(c.write_allocate, WriteAllocatePolicy::NoWriteAllocate);
        assert_eq!(c.mshr_entries, 512);
        assert_eq!(c.mshr_max_merge, 8);
        assert_eq!(c.miss_queue_size, 8);
        assert_eq!(c.capacity(), 4 * 64 * 128);
        assert_eq!(c.sectors_per_line(), 4);
    }

    #[test]
    fn parses_l2_string() {
        let c = CacheConfig::parse(L2).unwrap();
        assert_eq!(c.write_policy, WritePolicy::WriteBack);
        assert_eq!(c.write_allocate, WriteAllocatePolicy::WriteAllocate);
        assert_eq!(c.assoc, 24);
    }

    #[test]
    fn rejects_malformed() {
        assert!(CacheConfig::parse("garbage").is_err());
        assert!(CacheConfig::parse("Z:4:128:64,L:L:m:N:L,A:512:8,8:0,32")
            .is_err());
        // nsets not a power of two
        assert!(CacheConfig::parse("S:3:128:64,L:L:m:N:L,A:512:8,8:0,32")
            .is_err());
        // zero mshr entries
        assert!(CacheConfig::parse("S:4:128:64,L:L:m:N:L,A:0:8,8:0,32")
            .is_err());
    }

    #[test]
    fn address_decomposition() {
        let c = CacheConfig::parse(L2).unwrap();
        let addr = 0xDEAD_BEEF_u64;
        assert_eq!(c.block_addr(addr), addr & !127);
        assert!(c.sector_of(addr) < 4);
        assert!(c.set_of(addr) < c.nsets);
        // same line -> same set regardless of sector
        assert_eq!(c.set_of(addr), c.set_of(c.block_addr(addr)));
        // consecutive lines spread across sets (linear or xor)
        let s0 = c.set_of(0);
        let s1 = c.set_of(128);
        assert_ne!(s0, s1);
    }

    #[test]
    fn normal_cache_single_sector() {
        let c = CacheConfig::parse("N:64:128:8,L:B:m:W:L,A:64:8,16:0,32")
            .unwrap();
        assert_eq!(c.sectors_per_line(), 1);
        assert_eq!(c.sector_of(96), 0);
    }

    #[test]
    fn xor_hash_differs_from_linear_somewhere() {
        let lin =
            CacheConfig::parse("S:32:128:24,L:B:m:W:L,A:192:4,32:0,32")
                .unwrap();
        let xor =
            CacheConfig::parse("S:32:128:24,L:B:m:W:X,A:192:4,32:0,32")
                .unwrap();
        let diff = (0..1024u64)
            .map(|i| i * 128 * 32) // stride hitting one linear set
            .filter(|&a| lin.set_of(a) != xor.set_of(a))
            .count();
        assert!(diff > 0, "xor hash never diverged from linear");
    }
}
