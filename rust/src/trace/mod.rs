//! Trace model — the Accel-Sim front-end substrate.
//!
//! Accel-Sim drives GPGPU-Sim from NVBit SASS traces: a `kernelslist.g`
//! command file naming memcpy commands and per-kernel trace files. We
//! reproduce that shape with a compact, deterministic text format:
//!
//! * [`TraceCommand`] — one `kernelslist.g` line (memcpy or kernel).
//! * [`KernelTrace`] — grid/block geometry, stream id, and per-warp
//!   instruction lists ([`TraceOp`]).
//! * [`MemInstr`] — a warp-level memory instruction in base+stride form
//!   (lane *i* accesses `base + i*stride`), which keeps the paper's
//!   coalesced microbenchmarks exact while staying compact.
//!
//! [`io`] serializes/parses both file kinds; [`crate::workloads`]
//! generates them programmatically.

pub mod io;

use crate::{KernelUid, StreamId};

/// CUDA `dim3`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dim3 {
    pub x: u32,
    pub y: u32,
    pub z: u32,
}

impl Dim3 {
    /// 1-D helper.
    pub const fn linear(x: u32) -> Self {
        Self { x, y: 1, z: 1 }
    }

    /// Total element count.
    pub const fn count(&self) -> u64 {
        self.x as u64 * self.y as u64 * self.z as u64
    }
}

/// Memory space of an access (drives which cache hierarchy it uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemSpace {
    Global,
    Local,
    Const,
    Texture,
}

impl MemSpace {
    /// Trace-file token.
    pub const fn token(self) -> &'static str {
        match self {
            MemSpace::Global => "global",
            MemSpace::Local => "local",
            MemSpace::Const => "const",
            MemSpace::Texture => "texture",
        }
    }

    /// Parse a trace-file token.
    pub fn from_token(s: &str) -> Option<Self> {
        match s {
            "global" => Some(MemSpace::Global),
            "local" => Some(MemSpace::Local),
            "const" => Some(MemSpace::Const),
            "texture" => Some(MemSpace::Texture),
            _ => None,
        }
    }
}

/// A warp-level memory instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemInstr {
    /// Program counter (for dedup/debug only).
    pub pc: u32,
    pub space: MemSpace,
    pub is_write: bool,
    /// Bytes accessed per thread (4 for float, 8 for u64 ...).
    pub size: u8,
    /// Address accessed by the lowest active lane.
    pub base_addr: u64,
    /// Byte stride between consecutive lanes (0 = all lanes same addr).
    pub stride: i64,
    /// Active lane mask.
    pub active_mask: u32,
    /// `ld.global.cg` — cache only in L2, bypass L1 (paper §5.1 uses
    /// this to make the pointer-chase L2-deterministic).
    pub l1_bypass: bool,
}

impl MemInstr {
    /// Addresses touched by active lanes.
    pub fn lane_addrs(&self) -> impl Iterator<Item = u64> + '_ {
        (0..32u32).filter_map(move |lane| {
            (self.active_mask >> lane & 1 == 1).then(|| {
                (self.base_addr as i64 + lane as i64 * self.stride) as u64
            })
        })
    }

    /// Number of active lanes.
    pub fn active_lanes(&self) -> u32 {
        self.active_mask.count_ones()
    }
}

/// One warp-level instruction slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOp {
    /// Memory instruction.
    Mem(MemInstr),
    /// `count` back-to-back non-memory instructions (run-length encoded;
    /// each costs `SimConfig::alu_latency` pipeline occupancy).
    Alu { count: u32 },
}

/// Instruction list of one warp within one thread block.
pub type WarpOps = Vec<TraceOp>;

/// Per-TB trace: one op list per warp.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TbTrace {
    pub warps: Vec<WarpOps>,
}

/// A full kernel trace (the `.traceg` analogue).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelTrace {
    pub name: String,
    /// Trace-local kernel id (Accel-Sim's `kernel id`); the simulator
    /// assigns the runtime uid at launch.
    pub kernel_id: KernelUid,
    pub grid: Dim3,
    pub block: Dim3,
    /// CUDA stream this launch was captured on.
    pub stream_id: StreamId,
    pub shared_mem_bytes: u32,
    /// One entry per thread block, in dispatch order.
    pub tbs: Vec<TbTrace>,
}

impl KernelTrace {
    /// Warps per thread block (ceil of threads/32).
    pub fn warps_per_tb(&self) -> u32 {
        self.block.count().div_ceil(32) as u32
    }

    /// Total memory instructions in the trace.
    pub fn mem_instr_count(&self) -> u64 {
        self.tbs
            .iter()
            .flat_map(|tb| tb.warps.iter())
            .flatten()
            .filter(|op| matches!(op, TraceOp::Mem(_)))
            .count() as u64
    }

    /// Consistency checks (TB count matches grid, warp counts match
    /// block dims).
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(
            self.tbs.len() as u64 == self.grid.count(),
            "kernel '{}': {} TB traces for grid of {}",
            self.name, self.tbs.len(), self.grid.count()
        );
        let wpt = self.warps_per_tb() as usize;
        for (i, tb) in self.tbs.iter().enumerate() {
            ensure!(
                tb.warps.len() == wpt,
                "kernel '{}': TB {i} has {} warps, want {wpt}",
                self.name, tb.warps.len()
            );
        }
        Ok(())
    }
}

/// One `kernelslist.g` command.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceCommand {
    /// `MemcpyHtoD,<dst>,<bytes>` — modeled as a bulk DRAM write that
    /// warms nothing (matches Accel-Sim, which replays memcpys only to
    /// populate functional state).
    MemcpyHtoD { dst: u64, bytes: u64 },
    /// A kernel launch, by trace file name.
    Kernel { file: String },
}

/// A fully-loaded workload: the command list with kernel traces resolved.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    /// Launch-ordered kernels.
    pub kernels: Vec<KernelTrace>,
    /// Host-to-device copies preceding the kernels.
    pub memcpys: Vec<(u64, u64)>,
}

impl Workload {
    /// Distinct stream ids, ascending.
    pub fn streams(&self) -> Vec<StreamId> {
        let mut s: Vec<_> = self.kernels.iter().map(|k| k.stream_id)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Total memory instructions across kernels.
    pub fn mem_instr_count(&self) -> u64 {
        self.kernels.iter().map(|k| k.mem_instr_count()).sum()
    }

    /// Validate every kernel.
    pub fn validate(&self) -> anyhow::Result<()> {
        for k in &self.kernels {
            k.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mi(base: u64, stride: i64, mask: u32) -> MemInstr {
        MemInstr {
            pc: 0,
            space: MemSpace::Global,
            is_write: false,
            size: 4,
            base_addr: base,
            stride,
            active_mask: mask,
            l1_bypass: false,
        }
    }

    #[test]
    fn dim3_count() {
        assert_eq!(Dim3::linear(7).count(), 7);
        assert_eq!(Dim3 { x: 2, y: 3, z: 4 }.count(), 24);
    }

    #[test]
    fn lane_addrs_full_mask() {
        let m = mi(0x1000, 4, u32::MAX);
        let addrs: Vec<u64> = m.lane_addrs().collect();
        assert_eq!(addrs.len(), 32);
        assert_eq!(addrs[0], 0x1000);
        assert_eq!(addrs[31], 0x1000 + 31 * 4);
        assert_eq!(m.active_lanes(), 32);
    }

    #[test]
    fn lane_addrs_partial_mask() {
        let m = mi(0x2000, 8, 0b101);
        let addrs: Vec<u64> = m.lane_addrs().collect();
        assert_eq!(addrs, vec![0x2000, 0x2000 + 16]);
    }

    #[test]
    fn lane_addrs_zero_stride() {
        let m = mi(0x3000, 0, 0xF);
        let addrs: Vec<u64> = m.lane_addrs().collect();
        assert_eq!(addrs, vec![0x3000; 4]);
    }

    #[test]
    fn kernel_trace_validation() {
        let k = KernelTrace {
            name: "k".into(),
            kernel_id: 1,
            grid: Dim3::linear(2),
            block: Dim3::linear(64),
            stream_id: 0,
            shared_mem_bytes: 0,
            tbs: vec![
                TbTrace { warps: vec![vec![], vec![]] },
                TbTrace { warps: vec![vec![], vec![]] },
            ],
        };
        k.validate().unwrap();
        assert_eq!(k.warps_per_tb(), 2);

        let mut bad = k.clone();
        bad.tbs.pop();
        assert!(bad.validate().is_err());

        let mut bad2 = k;
        bad2.tbs[0].warps.pop();
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn workload_streams_sorted_dedup() {
        let mk = |sid| KernelTrace {
            name: "k".into(),
            kernel_id: 1,
            grid: Dim3::linear(1),
            block: Dim3::linear(32),
            stream_id: sid,
            shared_mem_bytes: 0,
            tbs: vec![TbTrace { warps: vec![vec![]] }],
        };
        let w = Workload {
            kernels: vec![mk(3), mk(1), mk(3)],
            memcpys: vec![],
        };
        assert_eq!(w.streams(), vec![1, 3]);
    }

    #[test]
    fn mem_instr_count_counts_only_mem() {
        let k = KernelTrace {
            name: "k".into(),
            kernel_id: 1,
            grid: Dim3::linear(1),
            block: Dim3::linear(32),
            stream_id: 0,
            shared_mem_bytes: 0,
            tbs: vec![TbTrace {
                warps: vec![vec![
                    TraceOp::Alu { count: 5 },
                    TraceOp::Mem(mi(0, 4, u32::MAX)),
                    TraceOp::Mem(mi(128, 4, u32::MAX)),
                ]],
            }],
        };
        assert_eq!(k.mem_instr_count(), 2);
    }
}
