//! Trace (de)serialization — the `kernelslist.g` / `.traceg` analogue.
//!
//! Command list format (one command per line, `#` comments):
//!
//! ```text
//! MemcpyHtoD,0x00007f0000000000,4194304
//! kernel-1.traceg
//! kernel-2.traceg
//! ```
//!
//! Kernel trace format (header then per-TB, per-warp op lines):
//!
//! ```text
//! -kernel name = saxpy
//! -kernel id = 1
//! -grid dim = (4096,1,1)
//! -block dim = (256,1,1)
//! -cuda stream id = 0
//! -shmem = 0
//! #BEGIN_TB 0
//! #warp 0
//! mem R global 4 0x7f0000000000 4 0xffffffff cg=0
//! alu 2
//! mem W global 4 0x7f0000100000 4 0xffffffff cg=0
//! #END_TB
//! ```

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{
    Dim3, KernelTrace, MemInstr, MemSpace, TbTrace, TraceCommand, TraceOp,
    Workload,
};

// ---------------------------------------------------------------------------
// command list
// ---------------------------------------------------------------------------

/// Render a command list.
pub fn write_commands(cmds: &[TraceCommand]) -> String {
    let mut out = String::new();
    for c in cmds {
        match c {
            TraceCommand::MemcpyHtoD { dst, bytes } => {
                let _ = writeln!(out, "MemcpyHtoD,{dst:#x},{bytes}");
            }
            TraceCommand::Kernel { file } => {
                let _ = writeln!(out, "{file}");
            }
        }
    }
    out
}

/// Parse a command list.
pub fn parse_commands(text: &str) -> Result<Vec<TraceCommand>> {
    let mut cmds = Vec::new();
    for (n, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("MemcpyHtoD,") {
            let (dst, bytes) = rest
                .split_once(',')
                .with_context(|| format!("line {}: bad memcpy", n + 1))?;
            cmds.push(TraceCommand::MemcpyHtoD {
                dst: parse_u64(dst.trim())?,
                bytes: bytes.trim().parse()?,
            });
        } else {
            cmds.push(TraceCommand::Kernel { file: line.to_string() });
        }
    }
    Ok(cmds)
}

fn parse_u64(s: &str) -> Result<u64> {
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).context("hex literal")
    } else {
        s.parse().context("decimal literal")
    }
}

// ---------------------------------------------------------------------------
// kernel trace
// ---------------------------------------------------------------------------

/// Render one kernel trace.
pub fn write_kernel(k: &KernelTrace) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "-kernel name = {}", k.name);
    let _ = writeln!(out, "-kernel id = {}", k.kernel_id);
    let _ = writeln!(out, "-grid dim = ({},{},{})",
                     k.grid.x, k.grid.y, k.grid.z);
    let _ = writeln!(out, "-block dim = ({},{},{})",
                     k.block.x, k.block.y, k.block.z);
    let _ = writeln!(out, "-cuda stream id = {}", k.stream_id);
    let _ = writeln!(out, "-shmem = {}", k.shared_mem_bytes);
    for (i, tb) in k.tbs.iter().enumerate() {
        let _ = writeln!(out, "#BEGIN_TB {i}");
        for (w, ops) in tb.warps.iter().enumerate() {
            let _ = writeln!(out, "#warp {w}");
            for op in ops {
                match op {
                    TraceOp::Alu { count } => {
                        let _ = writeln!(out, "alu {count}");
                    }
                    TraceOp::Mem(m) => {
                        let _ = writeln!(
                            out,
                            "mem {} {} {} {:#x} {} {:#010x} cg={}",
                            if m.is_write { "W" } else { "R" },
                            m.space.token(),
                            m.size,
                            m.base_addr,
                            m.stride,
                            m.active_mask,
                            m.l1_bypass as u8,
                        );
                    }
                }
            }
        }
        let _ = writeln!(out, "#END_TB");
    }
    out
}

/// Parse one kernel trace.
pub fn parse_kernel(text: &str) -> Result<KernelTrace> {
    let mut name = None;
    let mut kernel_id = None;
    let mut grid = None;
    let mut block = None;
    let mut stream_id = None;
    let mut shmem = 0u32;
    let mut tbs: Vec<TbTrace> = Vec::new();
    let mut cur_tb: Option<TbTrace> = None;
    let mut pc = 0u32;

    for (n, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let err = |msg: &str| format!("trace line {}: {msg}", n + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('-') {
            let (k, v) = rest
                .split_once('=')
                .with_context(|| err("header missing '='"))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "kernel name" => name = Some(v.to_string()),
                "kernel id" => kernel_id = Some(v.parse()?),
                "grid dim" => grid = Some(parse_dim3(v)?),
                "block dim" => block = Some(parse_dim3(v)?),
                "cuda stream id" => stream_id = Some(v.parse()?),
                "shmem" => shmem = v.parse()?,
                other => bail!(err(&format!("unknown header '{other}'"))),
            }
        } else if let Some(_idx) = line.strip_prefix("#BEGIN_TB") {
            if cur_tb.is_some() {
                bail!(err("nested BEGIN_TB"));
            }
            cur_tb = Some(TbTrace::default());
        } else if line == "#END_TB" {
            tbs.push(cur_tb.take().with_context(|| err("stray END_TB"))?);
        } else if line.strip_prefix("#warp").is_some() {
            cur_tb
                .as_mut()
                .with_context(|| err("warp outside TB"))?
                .warps
                .push(Vec::new());
        } else if let Some(rest) = line.strip_prefix("alu ") {
            let ops = &mut cur_tb
                .as_mut()
                .and_then(|tb| tb.warps.last_mut())
                .with_context(|| err("op outside warp"))?;
            ops.push(TraceOp::Alu { count: rest.trim().parse()? });
        } else if let Some(rest) = line.strip_prefix("mem ") {
            let f: Vec<&str> = rest.split_whitespace().collect();
            if f.len() != 7 {
                bail!(err("mem line needs 7 fields: \
                           dir space size base stride mask cg="));
            }
            let is_write = match f[0] {
                "R" => false,
                "W" => true,
                _ => bail!(err("mem dir must be R or W")),
            };
            let space = MemSpace::from_token(f[1])
                .with_context(|| err("bad mem space"))?;
            let l1_bypass = match f[6] {
                "cg=0" => false,
                "cg=1" => true,
                _ => bail!(err("last mem field must be cg=0|1")),
            };
            let instr = MemInstr {
                pc,
                space,
                is_write,
                size: f[2].parse()?,
                base_addr: parse_u64(f[3])?,
                stride: f[4].parse()?,
                active_mask: parse_mask(f[5])?,
                l1_bypass,
            };
            cur_tb
                .as_mut()
                .and_then(|tb| tb.warps.last_mut())
                .with_context(|| err("op outside warp"))?
                .push(TraceOp::Mem(instr));
        } else if line.starts_with('#') {
            continue; // comment
        } else {
            bail!(err(&format!("unrecognized line '{line}'")));
        }
        pc += 1;
    }
    if cur_tb.is_some() {
        bail!("unterminated BEGIN_TB");
    }
    let k = KernelTrace {
        name: name.context("missing kernel name")?,
        kernel_id: kernel_id.context("missing kernel id")?,
        grid: grid.context("missing grid dim")?,
        block: block.context("missing block dim")?,
        stream_id: stream_id.context("missing stream id")?,
        shared_mem_bytes: shmem,
        tbs,
    };
    k.validate()?;
    Ok(k)
}

fn parse_dim3(s: &str) -> Result<Dim3> {
    let inner = s
        .trim()
        .strip_prefix('(')
        .and_then(|x| x.strip_suffix(')'))
        .with_context(|| format!("dim3 '{s}' not parenthesized"))?;
    let parts: Vec<&str> = inner.split(',').collect();
    if parts.len() != 3 {
        bail!("dim3 '{s}' needs 3 components");
    }
    Ok(Dim3 {
        x: parts[0].trim().parse()?,
        y: parts[1].trim().parse()?,
        z: parts[2].trim().parse()?,
    })
}

fn parse_mask(s: &str) -> Result<u32> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        u32::from_str_radix(hex, 16).context("mask literal")
    } else {
        s.parse().context("mask literal")
    }
}

// ---------------------------------------------------------------------------
// workload-level helpers
// ---------------------------------------------------------------------------

/// Write a whole [`Workload`] to `dir` as `kernelslist.g` + one trace
/// file per kernel. Returns the command-list path.
pub fn write_workload(w: &Workload, dir: &Path) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let mut cmds = Vec::new();
    for (dst, bytes) in &w.memcpys {
        cmds.push(TraceCommand::MemcpyHtoD { dst: *dst, bytes: *bytes });
    }
    for (i, k) in w.kernels.iter().enumerate() {
        let file = format!("kernel-{}.traceg", i + 1);
        std::fs::write(dir.join(&file), write_kernel(k))
            .with_context(|| format!("writing {file}"))?;
        cmds.push(TraceCommand::Kernel { file });
    }
    let list = dir.join("kernelslist.g");
    std::fs::write(&list, write_commands(&cmds))?;
    Ok(list)
}

/// Load a workload from a `kernelslist.g` path.
pub fn load_workload(list_path: &Path) -> Result<Workload> {
    let dir = list_path.parent().unwrap_or(Path::new("."));
    let cmds = parse_commands(&std::fs::read_to_string(list_path)
        .with_context(|| format!("reading {}", list_path.display()))?)?;
    let mut w = Workload::default();
    for c in cmds {
        match c {
            TraceCommand::MemcpyHtoD { dst, bytes } => {
                w.memcpys.push((dst, bytes));
            }
            TraceCommand::Kernel { file } => {
                let text = std::fs::read_to_string(dir.join(&file))
                    .with_context(|| format!("reading {file}"))?;
                w.kernels.push(parse_kernel(&text)
                    .with_context(|| format!("parsing {file}"))?);
            }
        }
    }
    w.validate()?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_kernel() -> KernelTrace {
        KernelTrace {
            name: "saxpy".into(),
            kernel_id: 3,
            grid: Dim3::linear(2),
            block: Dim3::linear(64),
            stream_id: 7,
            shared_mem_bytes: 0,
            tbs: vec![
                TbTrace {
                    warps: vec![
                        vec![
                            TraceOp::Mem(MemInstr {
                                pc: 0,
                                space: MemSpace::Global,
                                is_write: false,
                                size: 4,
                                base_addr: 0x7f00_0000_0000,
                                stride: 4,
                                active_mask: u32::MAX,
                                l1_bypass: false,
                            }),
                            TraceOp::Alu { count: 2 },
                            TraceOp::Mem(MemInstr {
                                pc: 2,
                                space: MemSpace::Global,
                                is_write: true,
                                size: 4,
                                base_addr: 0x7f00_0010_0000,
                                stride: 4,
                                active_mask: 0x0000_FFFF,
                                l1_bypass: true,
                            }),
                        ],
                        vec![TraceOp::Alu { count: 1 }],
                    ],
                },
                TbTrace { warps: vec![vec![], vec![]] },
            ],
        }
    }

    #[test]
    fn kernel_roundtrip() {
        let k = sample_kernel();
        let text = write_kernel(&k);
        let parsed = parse_kernel(&text).unwrap();
        // pc is re-assigned by line order; compare modulo pc
        assert_eq!(parsed.name, k.name);
        assert_eq!(parsed.kernel_id, k.kernel_id);
        assert_eq!(parsed.grid, k.grid);
        assert_eq!(parsed.block, k.block);
        assert_eq!(parsed.stream_id, k.stream_id);
        assert_eq!(parsed.tbs.len(), k.tbs.len());
        let ops = &parsed.tbs[0].warps[0];
        match (&ops[0], &ops[2]) {
            (TraceOp::Mem(a), TraceOp::Mem(b)) => {
                assert_eq!(a.base_addr, 0x7f00_0000_0000);
                assert!(!a.is_write && !a.l1_bypass);
                assert_eq!(b.active_mask, 0x0000_FFFF);
                assert!(b.is_write && b.l1_bypass);
            }
            other => panic!("unexpected ops {other:?}"),
        }
    }

    #[test]
    fn commands_roundtrip() {
        let cmds = vec![
            TraceCommand::MemcpyHtoD { dst: 0x7f00_0000_0000, bytes: 4096 },
            TraceCommand::Kernel { file: "kernel-1.traceg".into() },
            TraceCommand::Kernel { file: "kernel-2.traceg".into() },
        ];
        let text = write_commands(&cmds);
        assert_eq!(parse_commands(&text).unwrap(), cmds);
    }

    #[test]
    fn parse_rejects_malformed_kernel() {
        assert!(parse_kernel("").is_err());
        assert!(parse_kernel("-kernel name = x\n").is_err());
        // mem op outside a warp
        let bad = "-kernel name = x\n-kernel id = 1\n\
                   -grid dim = (1,1,1)\n-block dim = (32,1,1)\n\
                   -cuda stream id = 0\n-shmem = 0\n\
                   mem R global 4 0x0 4 0xffffffff cg=0\n";
        assert!(parse_kernel(bad).is_err());
        // unterminated TB
        let bad2 = "-kernel name = x\n-kernel id = 1\n\
                    -grid dim = (1,1,1)\n-block dim = (32,1,1)\n\
                    -cuda stream id = 0\n-shmem = 0\n#BEGIN_TB 0\n#warp 0\n";
        assert!(parse_kernel(bad2).is_err());
    }

    #[test]
    fn workload_write_load_roundtrip() {
        let w = Workload {
            kernels: vec![sample_kernel()],
            memcpys: vec![(0x10_0000, 8192)],
        };
        let dir = std::env::temp_dir().join("streamsim_trace_io_test");
        let _ = std::fs::remove_dir_all(&dir);
        let list = write_workload(&w, &dir).unwrap();
        let loaded = load_workload(&list).unwrap();
        assert_eq!(loaded.kernels.len(), 1);
        assert_eq!(loaded.memcpys, vec![(0x10_0000, 8192)]);
        assert_eq!(loaded.kernels[0].name, "saxpy");
        assert_eq!(loaded.kernels[0].mem_instr_count(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
