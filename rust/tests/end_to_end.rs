//! Integration: full-size paper workloads through the complete stack
//! (trace generation → simulation → stats → timeline), on the mini GPU
//! preset. These are the heavyweight runs; `cargo test --release`
//! keeps them in seconds.

use streamsim::cache::access::AccessType;
use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::stats::StatDomain;
use streamsim::workloads;

fn run(bench: &str, preset: &str) -> GpuSim {
    let g = workloads::generate(bench).unwrap();
    let cfg = SimConfig::preset(preset).unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    sim
}

#[test]
fn benchmark_1_stream_full_size() {
    // the paper's N = 1<<20, 256 thr/blk — 4096 TBs per kernel
    let g = workloads::generate("bench1").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4);
    // analytic L1 totals hold at full size
    for (s, want) in &g.expected.l1_reads {
        let got = stats.l1().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccR);
        assert_eq!(got, *want, "stream {s} reads");
    }
    for (s, want) in &g.expected.l1_writes {
        let got = stats.l1().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} writes");
    }
    // L2 write-through totals
    for (s, want) in &g.expected.l2_writes {
        let got = stats.l2().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} L2 writes");
    }
}

#[test]
fn deepbench_full_trace_runs() {
    let sim = run("deepbench", "sm7_titanv_mini");
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4); // 2 streams x (gemm + bias)
    assert!(stats.total_cycles > 0);
    // the bias kernel depends on the gemm within each stream
    for s in [1u64, 2] {
        let f: Vec<_> = stats.kernel_times.finished().into_iter()
            .filter(|(st, _, _)| *st == s).collect();
        assert_eq!(f.len(), 2);
        assert!(f[0].2.end_cycle <= f[1].2.start_cycle);
    }
}

#[test]
fn titanv_full_preset_runs_l2_lat() {
    // the real 80-SM TITAN V geometry on the small workload
    let sim = run("l2_lat", "sm7_titanv");
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4);
    for s in 1..=4u64 {
        let t = stats.l2().stream_table(s).unwrap();
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccR), 1);
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccW), 1);
    }
}

#[test]
fn cli_end_to_end_validate_all_benches() {
    use streamsim::cli::{execute, Command};
    for bench in ["l2_lat", "bench1_mini", "deepbench_mini"] {
        let out = execute(Command::Validate {
            bench: bench.into(),
            preset: if bench == "l2_lat" { "minimal" }
                    else { "sm7_titanv_mini" }.into(),
            figure: false,
        })
        .unwrap_or_else(|e| panic!("{bench}: {e:#}"));
        assert!(out.contains("ALL CHECKS PASSED"), "{bench}:\n{out}");
    }
}

#[test]
fn timeline_renders_for_full_runs() {
    let sim = run("bench1_mini", "sm7_titanv_mini");
    let gantt = sim.render_timeline(64);
    assert!(gantt.contains("stream   0"));
    assert!(gantt.contains("stream   1"));
    let csv = streamsim::timeline::to_csv(&sim.stats().kernel_times);
    assert_eq!(csv.lines().count(), 5); // header + 4 kernels
}

#[test]
fn per_stream_dram_icnt_extensions_end_to_end() {
    let sim = run("deepbench_mini", "sm7_titanv_mini");
    let engine = &sim.stats().engine;
    let dram = engine.per_stream(StatDomain::Dram);
    let icnt = engine.per_stream(StatDomain::Icnt);
    assert!(dram.iter().any(|(s, _)| *s == 1)
            && dram.iter().any(|(s, _)| *s == 2),
            "both streams must reach DRAM: {dram:?}");
    assert!(icnt.iter().any(|(s, n)| *s == 1 && *n > 0)
            && icnt.iter().any(|(s, n)| *s == 2 && *n > 0),
            "both streams must cross the icnt: {icnt:?}");
    // the power domain is fed by the same engine, per stream
    let power = sim.stats().engine.power_stats();
    assert!(power.per_stream[&1].total_pj() > 0.0);
    assert!(power.per_stream[&2].total_pj() > 0.0);
    assert_eq!(engine.dropped_responses(), 0);
}

#[test]
fn sum_invariant_every_domain_full_workload() {
    // Σ_streams per_stream == exact, for DRAM / interconnect / power
    // (the L1/L2 cases are covered by the validation harness)
    let tip = run("bench1_mini", "sm7_titanv_mini");
    let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    cfg.stat_mode = streamsim::stats::StatMode::AggregateExact;
    let g = workloads::generate("bench1_mini").unwrap();
    let mut exact = GpuSim::new(cfg).unwrap();
    exact.enqueue_workload(&g.workload).unwrap();
    exact.run().unwrap();
    for d in [StatDomain::Dram, StatDomain::Icnt, StatDomain::Power] {
        let t = tip.stats().engine.domain_total(d);
        let e = exact.stats().engine.domain_total(d);
        assert_eq!(t, e, "domain {}", d.name());
        assert!(t > 0, "domain {} empty", d.name());
    }
}
