//! Integration: full-size paper workloads through the complete stack
//! (trace generation → simulation → stats → timeline), on the mini GPU
//! preset — driven entirely through the `streamsim::api` facade
//! (`SimBuilder` → `SimSession` → `Snapshot`), the single supported
//! way to run the simulator. These are the heavyweight runs;
//! `cargo test --release` keeps them in seconds.

use streamsim::api::{AccessType, SimBuilder, Snapshot, StatDomain,
                     StatMode};
use streamsim::workloads;

/// Run a built-in bench to idle and take the final snapshot.
fn run(bench: &str, preset: &str) -> Snapshot {
    let mut session = SimBuilder::preset(preset)
        .bench(bench)
        .build()
        .unwrap_or_else(|e| panic!("{bench}/{preset}: {e}"));
    session.run_to_idle().unwrap();
    session.into_snapshot()
}

#[test]
fn benchmark_1_stream_full_size() {
    // the paper's N = 1<<20, 256 thr/blk — 4096 TBs per kernel
    let g = workloads::generate("bench1").unwrap();
    let mut session = SimBuilder::preset("sm7_titanv_mini")
        .workload(g.workload.clone())
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let snap = session.into_snapshot();
    assert_eq!(snap.kernels_done(), 4);
    // analytic L1 totals hold at full size
    for (s, want) in &g.expected.l1_reads {
        let got = snap.l1().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccR);
        assert_eq!(got, *want, "stream {s} reads");
    }
    for (s, want) in &g.expected.l1_writes {
        let got = snap.l1().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} writes");
    }
    // L2 write-through totals
    for (s, want) in &g.expected.l2_writes {
        let got = snap.l2().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} L2 writes");
    }
}

#[test]
fn deepbench_full_trace_runs() {
    let snap = run("deepbench", "sm7_titanv_mini");
    assert_eq!(snap.kernels_done(), 4); // 2 streams x (gemm + bias)
    assert!(snap.total_cycles() > 0);
    // the bias kernel depends on the gemm within each stream
    for s in [1u64, 2] {
        let f: Vec<_> = snap.kernel_times().finished().into_iter()
            .filter(|(st, _, _)| *st == s).collect();
        assert_eq!(f.len(), 2);
        assert!(f[0].2.end_cycle <= f[1].2.start_cycle);
    }
}

#[test]
fn titanv_full_preset_runs_l2_lat() {
    // the real 80-SM TITAN V geometry on the small workload
    let snap = run("l2_lat", "sm7_titanv");
    assert_eq!(snap.kernels_done(), 4);
    for s in 1..=4u64 {
        let t = snap.l2().stream_table(s).unwrap();
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccR), 1);
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccW), 1);
    }
}

#[test]
fn cli_end_to_end_validate_all_benches() {
    use streamsim::cli::{execute, Command};
    for bench in ["l2_lat", "bench1_mini", "deepbench_mini"] {
        let out = execute(Command::Validate {
            bench: bench.into(),
            preset: if bench == "l2_lat" { "minimal" }
                    else { "sm7_titanv_mini" }.into(),
            figure: false,
        })
        .unwrap_or_else(|e| panic!("{bench}: {e:#}"));
        assert!(out.contains("ALL CHECKS PASSED"), "{bench}:\n{out}");
    }
}

#[test]
fn timeline_renders_for_full_runs() {
    let snap = run("bench1_mini", "sm7_titanv_mini");
    let gantt = snap.render_timeline(64);
    assert!(gantt.contains("stream   0"));
    assert!(gantt.contains("stream   1"));
    let csv = streamsim::timeline::to_csv(snap.kernel_times());
    assert_eq!(csv.lines().count(), 5); // header + 4 kernels
}

#[test]
fn per_stream_dram_icnt_extensions_end_to_end() {
    let snap = run("deepbench_mini", "sm7_titanv_mini");
    let dram = snap.per_stream(StatDomain::Dram);
    let icnt = snap.per_stream(StatDomain::Icnt);
    assert!(dram.iter().any(|(s, _)| *s == 1)
            && dram.iter().any(|(s, _)| *s == 2),
            "both streams must reach DRAM: {dram:?}");
    assert!(icnt.iter().any(|(s, n)| *s == 1 && *n > 0)
            && icnt.iter().any(|(s, n)| *s == 2 && *n > 0),
            "both streams must cross the icnt: {icnt:?}");
    // the power domain is fed by the same engine, per stream
    let power = snap.power_stats();
    assert!(power.per_stream[&1].total_pj() > 0.0);
    assert!(power.per_stream[&2].total_pj() > 0.0);
    assert_eq!(snap.losses().dropped_responses, 0);
}

#[test]
fn sum_invariant_every_domain_full_workload() {
    // Σ_streams per_stream == exact, for DRAM / interconnect / power
    // (the L1/L2 cases are covered by the validation harness)
    let g = workloads::generate("bench1_mini").unwrap();
    let mut tip = SimBuilder::preset("sm7_titanv_mini")
        .workload(g.workload.clone())
        .build()
        .unwrap();
    tip.run_to_idle().unwrap();
    let tip = tip.into_snapshot();
    let mut exact = SimBuilder::preset("sm7_titanv_mini")
        .stat_mode(StatMode::AggregateExact)
        .workload(g.workload.clone())
        .build()
        .unwrap();
    exact.run_to_idle().unwrap();
    let exact = exact.into_snapshot();
    for d in [StatDomain::Dram, StatDomain::Icnt, StatDomain::Power] {
        let t = tip.domain_total(d);
        let e = exact.domain_total(d);
        assert_eq!(t, e, "domain {}", d.name());
        assert!(t > 0, "domain {} empty", d.name());
    }
}

#[test]
fn central_exchange_full_size_matches_sharded() {
    // end-to-end anchor on a full-size workload: the `icnt_sharded`
    // toggle is invisible in the results (the per-cycle matrix lives
    // in tests/determinism.rs)
    let g = workloads::generate("bench1_mini").unwrap();
    let json = |sharded: bool| {
        let mut s = SimBuilder::preset("sm7_titanv_mini")
            .set("icnt_sharded", if sharded { "1" } else { "0" })
            .sim_threads(4)
            .workload(g.workload.clone())
            .build()
            .unwrap();
        s.run_to_idle().unwrap();
        // labels match so the exported documents are comparable
        s.into_snapshot().to_json()
    };
    assert_eq!(json(true), json(false));
}
