//! Integration: full-size paper workloads through the complete stack
//! (trace generation → simulation → stats → timeline), on the mini GPU
//! preset. These are the heavyweight runs; `cargo test --release`
//! keeps them in seconds.

use streamsim::cache::access::AccessType;
use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::workloads;

fn run(bench: &str, preset: &str) -> GpuSim {
    let g = workloads::generate(bench).unwrap();
    let cfg = SimConfig::preset(preset).unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    sim
}

#[test]
fn benchmark_1_stream_full_size() {
    // the paper's N = 1<<20, 256 thr/blk — 4096 TBs per kernel
    let g = workloads::generate("bench1").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4);
    // analytic L1 totals hold at full size
    for (s, want) in &g.expected.l1_reads {
        let got = stats.l1.stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccR);
        assert_eq!(got, *want, "stream {s} reads");
    }
    for (s, want) in &g.expected.l1_writes {
        let got = stats.l1.stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} writes");
    }
    // L2 write-through totals
    for (s, want) in &g.expected.l2_writes {
        let got = stats.l2.stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccW);
        assert_eq!(got, *want, "stream {s} L2 writes");
    }
}

#[test]
fn deepbench_full_trace_runs() {
    let sim = run("deepbench", "sm7_titanv_mini");
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4); // 2 streams x (gemm + bias)
    assert!(stats.total_cycles > 0);
    // the bias kernel depends on the gemm within each stream
    for s in [1u64, 2] {
        let f: Vec<_> = stats.kernel_times.finished().into_iter()
            .filter(|(st, _, _)| *st == s).collect();
        assert_eq!(f.len(), 2);
        assert!(f[0].2.end_cycle <= f[1].2.start_cycle);
    }
}

#[test]
fn titanv_full_preset_runs_l2_lat() {
    // the real 80-SM TITAN V geometry on the small workload
    let sim = run("l2_lat", "sm7_titanv");
    let stats = sim.stats();
    assert_eq!(stats.kernels_done, 4);
    for s in 1..=4u64 {
        let t = stats.l2.stream_table(s).unwrap();
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccR), 1);
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccW), 1);
    }
}

#[test]
fn cli_end_to_end_validate_all_benches() {
    use streamsim::cli::{execute, Command};
    for bench in ["l2_lat", "bench1_mini", "deepbench_mini"] {
        let out = execute(Command::Validate {
            bench: bench.into(),
            preset: if bench == "l2_lat" { "minimal" }
                    else { "sm7_titanv_mini" }.into(),
            figure: false,
        })
        .unwrap_or_else(|e| panic!("{bench}: {e:#}"));
        assert!(out.contains("ALL CHECKS PASSED"), "{bench}:\n{out}");
    }
}

#[test]
fn timeline_renders_for_full_runs() {
    let sim = run("bench1_mini", "sm7_titanv_mini");
    let gantt = sim.render_timeline(64);
    assert!(gantt.contains("stream   0"));
    assert!(gantt.contains("stream   1"));
    let csv = streamsim::timeline::to_csv(&sim.stats().kernel_times);
    assert_eq!(csv.lines().count(), 5); // header + 4 kernels
}

#[test]
fn per_stream_dram_icnt_extensions_end_to_end() {
    let sim = run("deepbench_mini", "sm7_titanv_mini");
    let dram = sim.dram_per_stream();
    let icnt = sim.icnt_per_stream();
    assert!(dram.keys().any(|s| *s == 1) && dram.keys().any(|s| *s == 2),
            "both streams must reach DRAM: {dram:?}");
    assert!(icnt[&1] > 0 && icnt[&2] > 0);
}
