//! Integration: the paper's validation experiments (Figs. 2–5) as
//! assertions, run on the mini config so the suite stays fast.
//!
//! Acceptance criteria per figure are listed in DESIGN.md §4.

use streamsim::cache::access::{AccessOutcome, AccessType};
use streamsim::config::SimConfig;
use streamsim::harness::{all_passed, render_checks, run_three_configs};
use streamsim::workloads;

/// FIG2 acceptance: exact per-stream counts, clean == Σ tip, serialized
/// HIT ↔ concurrent MSHR_HIT shift.
#[test]
fn fig2_l2_lat_4stream() {
    let g = workloads::generate("l2_lat").unwrap();
    let cfg = SimConfig::preset("minimal").unwrap();
    let tw = run_three_configs(&cfg, &g).unwrap();
    let checks = tw.validate(&g);
    assert!(all_passed(&checks), "\n{}", render_checks(&checks));

    // per-stream exactness: each stream did exactly 1 L2 read and 1 L2
    // write (serviced outcomes)
    for s in 1..=4u64 {
        let t = tw.tip.stats.l2().stream_table(s).unwrap();
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccR), 1,
                   "stream {s} reads");
        assert_eq!(t.total_serviced_for_type(AccessType::GlobalAccW), 1,
                   "stream {s} writes");
    }

    // Fig. 2's green == orange for every L2 row (single partition, so
    // no same-cycle collisions -> clean is loss-free here)
    let fig = tw.figure("fig2");
    for r in fig.rows.iter().filter(|r| r.cache == "L2") {
        assert_eq!(r.tip_sum(), r.clean, "row {:?} {:?}",
                   r.access_type, r.outcome);
    }

    // serialized turns MSHR_HITs into HITs
    let conc = tw.tip.stats.l2().total_table();
    let ser = tw.tip_serialized.stats.l2().total_table();
    assert!(conc.total_for_outcome(AccessOutcome::MshrHit) > 0,
            "concurrent run must produce MSHR_HITs");
    assert_eq!(ser.total_for_outcome(AccessOutcome::MshrHit)
                   + ser.total_for_outcome(AccessOutcome::Hit),
               conc.total_for_outcome(AccessOutcome::MshrHit)
                   + conc.total_for_outcome(AccessOutcome::Hit),
               "HIT+MSHR_HIT conserved between gatings");
    assert!(ser.total_for_outcome(AccessOutcome::Hit)
                > conc.total_for_outcome(AccessOutcome::Hit));
}

/// FIG3 acceptance (benchmark_1_stream shape, mini size for speed).
#[test]
fn fig3_benchmark_1_stream_mini() {
    let g = workloads::generate("bench1_mini").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let tw = run_three_configs(&cfg, &g).unwrap();
    let checks = tw.validate(&g);
    assert!(all_passed(&checks), "\n{}", render_checks(&checks));

    // kernel 3 runs on stream 1 and its window overlaps stream 0's
    // kernels under concurrency (the paper's timeline)
    assert!(tw.tip.stats.kernel_times().cross_stream_overlaps() > 0);
    assert_eq!(
        tw.tip_serialized.stats.kernel_times()
            .cross_stream_overlaps(), 0);

    // stream attribution: both streams present in L1 stats with the
    // analytic totals
    for (s, want) in &g.expected.l1_reads {
        let got = tw.tip.stats.l1().stream_table(*s).unwrap()
            .total_serviced_for_type(AccessType::GlobalAccR);
        assert_eq!(got, *want, "stream {s}");
    }
}

/// FIG4 acceptance (benchmark_3_stream at full size — 256 TBs of 1024
/// threads; still fast on the mini GPU).
#[test]
fn fig4_benchmark_3_stream() {
    let g = workloads::generate("bench3").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let tw = run_three_configs(&cfg, &g).unwrap();
    let checks = tw.validate(&g);
    assert!(all_passed(&checks), "\n{}", render_checks(&checks));

    // the under-count claim: tip >= clean cell-wise AND the clean run
    // actually dropped increments on this multi-core workload
    assert!(tw.tip.stats.l1().total_table()
              .dominates(&tw.clean.stats.l1().total_table()));
    let dropped =
        tw.clean.stats.l1().dropped() + tw.clean.stats.l2().dropped();
    assert!(dropped > 0,
            "multi-core concurrent run should exhibit the clean-mode \
             same-cycle under-count (got 0 drops)");
}

/// FIG5 acceptance (DeepBench mini): trends only — Σ tip == exact,
/// overlap in concurrent mode, cross-stream MSHR merging on the shared
/// A panel.
#[test]
fn fig5_deepbench_mini() {
    let g = workloads::generate("deepbench_mini").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let tw = run_three_configs(&cfg, &g).unwrap();
    let checks = tw.validate(&g);
    assert!(all_passed(&checks), "\n{}", render_checks(&checks));

    // both streams recorded L2 traffic; the shared A panel produced
    // cross-stream reuse (hits or MSHR merges) in the concurrent run
    let l2 = tw.tip.stats.l2();
    let reuse: u64 = [1u64, 2]
        .iter()
        .map(|s| {
            let t = l2.stream_table(*s).unwrap();
            t.get(AccessType::GlobalAccR, AccessOutcome::Hit)
                + t.get(AccessType::GlobalAccR, AccessOutcome::MshrHit)
        })
        .sum();
    assert!(reuse > 0, "shared A panel must show cross-stream reuse");
}

/// The exit-log print fix (§3.1): each kernel exit prints only its own
/// stream's breakdown.
#[test]
fn exit_log_stream_selective_printing() {
    let g = workloads::generate("bench1_mini").unwrap();
    let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    cfg.stat_mode = streamsim::stats::StatMode::PerStream;
    let mut sim = streamsim::sim::GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    let log = &sim.stats().exit_log;
    assert_eq!(log.len(), 4, "one print per kernel exit");
    for entry in log {
        let header = entry.lines().next().unwrap().to_string();
        let stream = if header.contains("stream 0") { 0 } else { 1 };
        let other = 1 - stream;
        assert!(!entry.contains(&format!("(stream {other})")),
                "leaked stream {other} stats:\n{entry}");
    }
}

/// Kernel time tracking (§3.2): every kernel has a window; same-stream
/// kernels are ordered.
#[test]
fn kernel_time_windows_complete_and_ordered() {
    let g = workloads::generate("bench1_mini").unwrap();
    let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    let tw = run_three_configs(&cfg, &g).unwrap();
    let finished = tw.tip.stats.kernel_times().finished();
    assert_eq!(finished.len(), 4);
    // stream 0 kernels (k1, k2, k4) in order
    let s0: Vec<_> = finished.iter().filter(|(s, _, _)| *s == 0)
        .collect();
    assert_eq!(s0.len(), 3);
    for pair in s0.windows(2) {
        assert!(pair[0].2.end_cycle <= pair[1].2.start_cycle,
                "same-stream kernels must serialize");
    }
}

/// Golden per-kernel/per-stream count pins for the paper's §5
/// microbenchmarks (`benchmark_1_stream`, `benchmark_3_stream`): the
/// full per-stream L1/L2 hit+miss breakdown and the per-kernel exit
/// prints are snapshotted under `tests/golden/`, so a shard
/// merge-ordering bug shows up as a count diff, not a silent pass.
///
/// Blessing: run with `STREAMSIM_BLESS=1` (or delete the snapshot) to
/// regenerate; the first toolchain-equipped CI run creates the files
/// and committing them pins the counts for every run after. Analytic
/// serviced-count pins (derived from the generator, not the
/// simulator) are asserted unconditionally either way.
mod golden {
    use super::*;
    use std::fmt::Write as _;
    use std::path::PathBuf;
    use streamsim::sim::GpuSim;
    use streamsim::stats::StatMode;

    fn golden_path(bench: &str) -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("tests/golden")
            .join(format!("{bench}_counts.txt"))
    }

    /// Canonical per-stream per-cell dump of one tip-mode run.
    fn fingerprint(bench: &str) -> String {
        let g = workloads::generate(bench).unwrap();
        let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        let mut sim = GpuSim::new(cfg).unwrap();
        sim.enqueue_workload(&g.workload).unwrap();
        sim.run().unwrap();
        let stats = sim.stats();

        // always-on analytic pins (generator-derived, simulator-free)
        for (s, want) in &g.expected.l1_reads {
            let got = stats.l1().stream_table(*s).unwrap()
                .total_serviced_for_type(AccessType::GlobalAccR);
            assert_eq!(got, *want, "{bench}: stream {s} L1 reads");
        }
        for (s, want) in &g.expected.l1_writes {
            let got = stats.l1().stream_table(*s).unwrap()
                .total_serviced_for_type(AccessType::GlobalAccW);
            assert_eq!(got, *want, "{bench}: stream {s} L1 writes");
        }
        for (s, want) in &g.expected.l2_writes {
            let got = stats.l2().stream_table(*s).unwrap()
                .total_serviced_for_type(AccessType::GlobalAccW);
            assert_eq!(got, *want, "{bench}: stream {s} L2 writes");
        }

        let mut out = format!("bench={bench} kernels={} cycles={}\n",
                              stats.kernels_done, stats.total_cycles);
        for (label, view) in [("L1", stats.l1()), ("L2", stats.l2())] {
            for s in view.streams() {
                let t = view.stream_table(s).unwrap();
                for (ty, o, n) in t.iter_nonzero() {
                    let _ = writeln!(out, "{label} stream={s} {}.{}={n}",
                                     ty.name(), o.name());
                }
                let f = view.stream_fail_table(s).unwrap();
                for (ty, fo, n) in f.iter_nonzero() {
                    let _ = writeln!(
                        out, "{label} stream={s} fail {}.{}={n}",
                        ty.name(), fo.name());
                }
            }
        }
        // per-kernel windows + per-kernel per-stream breakdown prints
        for (stream, uid, k) in stats.kernel_times.finished() {
            let _ = writeln!(
                out, "kernel stream={stream} uid={uid} start={} end={}",
                k.start_cycle, k.end_cycle);
        }
        for entry in &stats.exit_log {
            out.push_str(entry);
        }
        out
    }

    fn check_golden(bench: &str) {
        let got = fingerprint(bench);
        let path = golden_path(bench);
        let bless =
            std::env::var("STREAMSIM_BLESS").as_deref() == Ok("1");
        if bless || !path.exists() {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &got).unwrap();
            eprintln!("blessed golden counts: {}", path.display());
            return;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            want, got,
            "{bench}: per-kernel/per-stream counts diverged from the \
             golden snapshot {} (rebless with STREAMSIM_BLESS=1 only \
             if the change is intended)",
            path.display());
    }

    #[test]
    fn golden_counts_benchmark_1_stream() {
        check_golden("bench1");
    }

    #[test]
    fn golden_counts_benchmark_3_stream() {
        check_golden("bench3");
    }

    /// The golden fingerprint itself must not depend on the thread
    /// count (belt over the determinism suite's JSON check, through
    /// the snapshot formatting path).
    #[test]
    fn golden_fingerprint_thread_count_independent() {
        let g = workloads::generate("bench1_mini").unwrap();
        let run = |threads: u32| {
            let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
            cfg.stat_mode = StatMode::PerStream;
            cfg.sim_threads = threads;
            let mut sim = GpuSim::new(cfg).unwrap();
            sim.enqueue_workload(&g.workload).unwrap();
            sim.run().unwrap();
            let stats = sim.stats();
            (stats.l1().total_table(), stats.l2().total_table(),
             stats.exit_log.clone())
        };
        assert_eq!(run(1), run(4));
    }
}

/// Property: for random mixed workloads, Σ-per-stream == exact holds on
/// every cell (the paper's core invariant, fuzzed at system level).
#[test]
fn property_sum_invariant_random_workloads() {
    use streamsim::stats::StatMode;
    use streamsim::trace::{Dim3, KernelTrace, MemInstr, MemSpace,
                           TbTrace, TraceOp, Workload};
    use streamsim::util::proptest_lite::run_cases;

    run_cases("system-sum-invariant", 0x5EED, 6, |g| {
        let nstreams = g.range(1, 5);
        let kernels: Vec<KernelTrace> = (0..nstreams)
            .map(|s| {
                let tbs = g.range(1, 5) as u32;
                KernelTrace {
                    name: format!("rk{s}"),
                    kernel_id: 1,
                    grid: Dim3::linear(tbs),
                    block: Dim3::linear(64),
                    stream_id: s,
                    shared_mem_bytes: 0,
                    tbs: (0..tbs)
                        .map(|tb| TbTrace {
                            warps: (0..2)
                                .map(|w| {
                                    let base = g.below(64) * 0x80
                                        + tb as u64 * 0x1000
                                        + w as u64 * 0x100;
                                    vec![TraceOp::Mem(MemInstr {
                                        pc: 0,
                                        space: MemSpace::Global,
                                        is_write: g.chance(0.3),
                                        size: 4,
                                        base_addr: 0x10_0000 + base,
                                        stride: 4,
                                        active_mask: u32::MAX,
                                        l1_bypass: g.chance(0.2),
                                    })]
                                })
                                .collect(),
                        })
                        .collect(),
                }
            })
            .collect();
        let w = Workload { kernels, memcpys: vec![] };

        use streamsim::stats::StatDomain;
        let scalar_domains =
            [StatDomain::Dram, StatDomain::Icnt, StatDomain::Power];
        let run = |mode: StatMode| {
            let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
            cfg.stat_mode = mode;
            let mut sim = streamsim::sim::GpuSim::new(cfg).unwrap();
            sim.enqueue_workload(&w).unwrap();
            sim.run().unwrap();
            let scalars = scalar_domains
                .map(|d| sim.stats().engine.domain_total(d));
            (sim.stats().l1().total_table(),
             sim.stats().l2().total_table(), scalars)
        };
        let (tip_l1, tip_l2, tip_scalars) = run(StatMode::PerStream);
        let (exact_l1, exact_l2, exact_scalars) =
            run(StatMode::AggregateExact);
        let (clean_l1, clean_l2, _) = run(StatMode::AggregateBuggy);
        assert_eq!(tip_l1, exact_l1);
        assert_eq!(tip_l2, exact_l2);
        // the Σ-invariant holds in the DRAM/icnt/power domains too
        assert_eq!(tip_scalars, exact_scalars);
        assert!(tip_l1.dominates(&clean_l1));
        assert!(tip_l2.dominates(&clean_l2));
    });
}
