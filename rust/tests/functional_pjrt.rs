//! Integration: the functional layer against the real AOT artifacts
//! (requires `make artifacts`; tests self-skip when absent so
//! `cargo test` stays runnable pre-AOT).

use streamsim::functional;
use streamsim::runtime::{default_artifact_dir, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping functional tests: run `make artifacts`");
        return None;
    }
    let mut rt = Runtime::new().expect("PJRT client");
    rt.load_dir(&dir).expect("artifacts load");
    Some(rt)
}

#[test]
fn stream_program_b3_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let r = functional::check_stream_program(&rt, "stream_program_b3",
                                             1 << 18)
        .unwrap();
    assert!(r.passed, "max_abs_err = {}", r.max_abs_err);
    assert_eq!(r.elements, 3 << 18);
}

#[test]
fn stream_program_b1_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let r = functional::check_stream_program(&rt, "stream_program_b1",
                                             1 << 20)
        .unwrap();
    assert!(r.passed, "max_abs_err = {}", r.max_abs_err);
}

#[test]
fn deepbench_gemm_mini_matches_quantized_oracle() {
    let Some(rt) = runtime() else { return };
    let r = functional::check_gemm(&rt, "deepbench_gemm_mini", 35, 512,
                                   256)
        .unwrap();
    assert!(r.passed, "max_abs_err = {}", r.max_abs_err);
    assert_eq!(r.elements, 35 * 256);
}

#[test]
fn deepbench_gemm_full_shape_runs() {
    let Some(rt) = runtime() else { return };
    // the paper's exact 35x1500x2560 fp16 GEMM
    let r = functional::check_gemm(&rt, "deepbench_gemm", 35, 2560, 1500)
        .unwrap();
    assert!(r.passed, "max_abs_err = {}", r.max_abs_err);
}

#[test]
fn stats_aggregate_exact_for_all_batch_sizes() {
    let Some(rt) = runtime() else { return };
    for events in [0usize, 1, 100, 10_000, 16_384] {
        let r = functional::check_stats_aggregate(&rt, events).unwrap();
        assert!(r.passed, "events={events}");
        assert_eq!(r.checksum, events as f64,
                   "total count must equal valid events");
    }
}

/// Cross-layer: the Pallas stats-aggregation artifact reproduces the
/// Rust simulator's own per-stream L2 stat cube for a real workload.
#[test]
fn pallas_aggregation_reproduces_simulator_stats() {
    use streamsim::cache::access::{AccessOutcome, AccessType};
    use streamsim::config::SimConfig;
    use streamsim::runtime::HostTensor;
    use streamsim::sim::GpuSim;
    use streamsim::stats::print::dense_rows;

    let Some(rt) = runtime() else { return };

    // run the fig2 workload, capture per-event stream/type/outcome by
    // replaying the stat tables into an event list
    let g = streamsim::workloads::generate("l2_lat").unwrap();
    let cfg = SimConfig::preset("minimal").unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();

    let n = 16384usize;
    let (mut sid, mut typ, mut outc, mut valid) =
        (vec![0i32; n], vec![0i32; n], vec![0i32; n], vec![0i32; n]);
    let mut i = 0;
    // streams 1..=4 -> event stream ids 1..=4 (cube has 8 slots)
    for s in sim.stats().l2().streams() {
        let rows = dense_rows(sim.stats().l2(), s);
        for (t, row) in rows.iter().enumerate() {
            for (o, count) in row.iter().enumerate() {
                for _ in 0..*count {
                    sid[i] = s as i32;
                    typ[i] = t as i32;
                    outc[i] = o as i32;
                    valid[i] = 1;
                    i += 1;
                }
            }
        }
    }
    let mk = |v: &[i32]| HostTensor::I32 { data: v.to_vec(),
                                           dims: vec![n] };
    let out = rt
        .execute("stats_aggregate",
                 &[mk(&sid), mk(&typ), mk(&outc), mk(&valid)])
        .unwrap();
    let cube = out[0].as_f32(); // [8, 10, 6]
    for s in 1..=4u64 {
        for t in AccessType::ALL {
            for o in AccessOutcome::ALL {
                let got = cube[(s as usize * 10 + t.idx()) * 6 + o.idx()];
                let want = sim.stats().l2().get(s, t, o) as f32;
                assert_eq!(got, want, "cell s={s} {t} {o}");
            }
        }
    }
}
