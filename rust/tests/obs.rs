//! Observability suite: event recording must be a pure *observer* —
//! turning it on must not change a single exported byte — and what it
//! records must agree with the simulator's own ground truth.
//!
//! Three properties are pinned:
//!
//! 1. **Byte-identity.** The full exported stats JSON is identical
//!    with `obs_enabled` on vs. off, across the `--sim-threads` 1/4 ×
//!    `tip`/`exact` matrix (the same fingerprint discipline as
//!    `tests/determinism.rs`).
//! 2. **Trace validity.** The Chrome `trace_event` document parses
//!    with the server's own strict JSON parser, has the expected
//!    top-level shape, and every event carries the required fields.
//! 3. **Span agreement.** The kernel spans reconstructed from the
//!    event stream equal the session's `KernelTimeTracker`
//!    (`gpu_kernel_time`) windows exactly.

use streamsim::api::{SimBuilder, StatMode};
use streamsim::obs::trace::kernel_spans;
use streamsim::server::json::{self, Json};
use streamsim::timeline;

/// Full stats document for `bench` with the given knobs.
fn fingerprint(bench: &str, mode: StatMode, threads: u32, obs: bool)
    -> String {
    let mut session = SimBuilder::preset("minimal")
        .stat_mode(mode)
        .sim_threads(threads)
        .obs_enabled(obs)
        .bench(bench)
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    session.into_snapshot().to_json()
}

#[test]
fn recording_never_changes_the_exported_bytes() {
    for bench in ["l2_lat", "bench3"] {
        for mode in [StatMode::PerStream, StatMode::AggregateExact] {
            for threads in [1u32, 4] {
                let off = fingerprint(bench, mode, threads, false);
                let on = fingerprint(bench, mode, threads, true);
                assert_eq!(
                    off, on,
                    "obs_enabled changed the document: {bench} \
                     {} threads={threads}",
                    mode.label());
            }
        }
    }
}

#[test]
fn recording_is_off_by_default() {
    let mut session = SimBuilder::preset("minimal")
        .bench("l2_lat")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    assert!(session.events().is_empty());
}

#[test]
fn trace_document_is_valid_and_cycle_stamped() {
    let mut session = SimBuilder::preset("minimal")
        .obs_enabled(true)
        .bench("l2_lat")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let total = session.cycle();
    let doc = session.trace_json();

    // parses with the server's own strict (no floats, no negatives)
    // parser — the same bytes the `trace` verb would splice in
    let v = json::parse(&doc).unwrap();
    assert_eq!(v.get("displayTimeUnit").and_then(Json::as_str),
               Some("ms"));
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let mut kernel_complete = 0;
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        assert!(matches!(ph, "X" | "i" | "M"), "unknown phase {ph}");
        assert!(e.get("pid").and_then(Json::as_u64).is_some());
        if ph != "M" {
            // timestamps are simulated cycles: bounded by the run
            let ts = e.get("ts").and_then(Json::as_u64).unwrap();
            assert!(ts <= total, "ts {ts} past end of run {total}");
        }
        if ph == "X"
            && e.get("cat").and_then(Json::as_str) == Some("kernel")
        {
            kernel_complete += 1;
            assert!(e
                .get("dur")
                .and_then(Json::as_u64)
                .is_some_and(|d| d >= 1));
        }
    }
    // every finished kernel shows up as a complete event
    let snap = session.snapshot();
    assert_eq!(kernel_complete,
               snap.kernel_times().finished().len());
}

#[test]
fn event_spans_agree_with_the_kernel_time_tracker() {
    let mut session = SimBuilder::preset("minimal")
        .obs_enabled(true)
        .bench("bench3")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();

    let spans = kernel_spans(session.events());
    let rebuilt = timeline::tracker_from_events(session.events());
    let snap = session.snapshot();
    let truth = snap.kernel_times();

    // pairwise: every span matches the tracker's window exactly
    assert_eq!(spans.len(), truth.finished().len());
    for (stream, uid, _name, start, end) in &spans {
        let w = truth
            .get(*stream, *uid)
            .unwrap_or_else(|| panic!("kernel {uid} untracked"));
        assert_eq!((*start, *end), (w.start_cycle, w.end_cycle),
                   "stream {stream} uid {uid}");
    }
    // and the rebuilt tracker is the tracker, wholesale
    assert_eq!(rebuilt.finished(), truth.finished());
    assert_eq!(rebuilt.cross_stream_overlaps(),
               truth.cross_stream_overlaps());
}

#[test]
fn interval_metrics_agree_with_the_snapshot_diff() {
    let mut session = SimBuilder::preset("minimal")
        .bench("l2_lat")
        .build()
        .unwrap();
    let before = session.snapshot();
    session.run_to_idle().unwrap();
    let after = session.snapshot();
    let diff = after.diff(&before).unwrap();
    let text = streamsim::obs::metrics::render_interval(
        after.total_cycles(), &diff);
    assert_eq!(
        streamsim::obs::metrics::sample_value(&text,
                                              "streamsim_cycle"),
        Some(after.total_cycles()));
    assert_eq!(
        streamsim::obs::metrics::sample_value(
            &text, "streamsim_interval_cycles"),
        Some(diff.cycles()));
    assert_eq!(
        streamsim::obs::metrics::sample_value(
            &text, "streamsim_interval_kernels_done"),
        Some(u64::from(diff.kernels_done())));
}
