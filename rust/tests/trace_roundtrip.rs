//! Integration: trace serialization round-trips and the disk-trace run
//! path (`streamsim run --trace`) matches the in-memory path exactly.

use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::trace::io;
use streamsim::workloads;

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("streamsim_it_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn every_generator_roundtrips_through_disk() {
    for bench in workloads::BENCHES {
        if bench == "deepbench" || bench == "bench1" || bench == "bench3" {
            continue; // large traces; covered by the mini variants
        }
        let g = workloads::generate(bench).unwrap();
        let dir = tmp(bench);
        let list = io::write_workload(&g.workload, &dir).unwrap();
        let loaded = io::load_workload(&list).unwrap();
        assert_eq!(loaded.kernels.len(), g.workload.kernels.len(),
                   "{bench}");
        for (a, b) in loaded.kernels.iter().zip(&g.workload.kernels) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.stream_id, b.stream_id);
            assert_eq!(a.grid, b.grid);
            assert_eq!(a.block, b.block);
            assert_eq!(a.mem_instr_count(), b.mem_instr_count());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn disk_trace_simulation_matches_in_memory() {
    let g = workloads::generate("l2_lat").unwrap();
    let dir = tmp("sim_equiv");
    let list = io::write_workload(&g.workload, &dir).unwrap();
    let loaded = io::load_workload(&list).unwrap();

    let run = |w: &streamsim::trace::Workload| {
        let cfg = SimConfig::preset("minimal").unwrap();
        let mut sim = GpuSim::new(cfg).unwrap();
        sim.enqueue_workload(w).unwrap();
        sim.run().unwrap();
        (sim.stats().l2().total_table(), sim.stats().total_cycles)
    };
    let (mem_table, mem_cycles) = run(&g.workload);
    let (disk_table, disk_cycles) = run(&loaded);
    assert_eq!(mem_table, disk_table,
               "stats must be identical for identical traces");
    assert_eq!(mem_cycles, disk_cycles, "timing must be deterministic");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn determinism_across_repeated_runs() {
    let g = workloads::generate("bench1_mini").unwrap();
    let run = || {
        let cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        let mut sim = GpuSim::new(cfg).unwrap();
        sim.enqueue_workload(&g.workload).unwrap();
        sim.run().unwrap();
        (
            sim.stats().l1().total_table(),
            sim.stats().l2().total_table(),
            sim.stats().total_cycles,
            streamsim::timeline::to_csv(&sim.stats().kernel_times),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0);
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2, "cycle-exact determinism");
    assert_eq!(a.3, b.3, "timeline determinism");
}

#[test]
fn config_file_layering_matches_cli_overrides() {
    let dir = tmp("cfg_layering");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("gpgpusim.config");
    std::fs::write(&cfg_path,
        "# paper §4 usage\n-gpgpu_concurrent_kernel_sm 1\n\
         -gpgpu_n_clusters 2\n-stat_mode tip\n").unwrap();
    let mut from_file = SimConfig::preset("sm7_titanv_mini").unwrap();
    from_file.apply_file(&cfg_path).unwrap();

    let mut from_cli = SimConfig::preset("sm7_titanv_mini").unwrap();
    let mut kv = std::collections::BTreeMap::new();
    kv.insert("gpgpu_concurrent_kernel_sm".into(), "1".into());
    kv.insert("gpgpu_n_clusters".into(), "2".into());
    kv.insert("stat_mode".into(), "tip".into());
    from_cli.apply_overrides(&kv).unwrap();

    assert_eq!(from_file.num_cores, from_cli.num_cores);
    assert_eq!(from_file.concurrent_kernel_sm,
               from_cli.concurrent_kernel_sm);
    assert_eq!(from_file.stat_mode, from_cli.stat_mode);
    std::fs::remove_dir_all(&dir).unwrap();
}
