//! Integration suite for the `SimService` layer: the warm-reuse
//! byte-identity contract across the thread/mode matrix (cold
//! session vs. warm-reused service vs. batch), bounded-queue
//! backpressure, shutdown-under-load draining, and the `service`
//! stats-JSON section's key golden.

use streamsim::api::{top_level_keys, BatchRunner, Priority,
                     ServiceError, SimBuilder, SimJob, SimService,
                     StatMode, SCHEMA_VERSION,
                     SERVICE_SECTION_KEYS};

fn scenario(sim_threads: u32, mode: StatMode) -> SimBuilder {
    SimBuilder::preset("sm7_titanv_mini")
        .stat_mode(mode)
        .sim_threads(sim_threads)
        .bench("l2_lat")
        .label("matrix")
}

/// The acceptance matrix: one scenario through (a) a fresh cold
/// `SimSession`, (b) a `SimService` whose single worker warm-reuses
/// its session, and (c) a `BatchRunner` — byte-identical versioned
/// stats JSON, across `sim_threads` 1/4 × tip/exact.
#[test]
fn warm_cold_and_batch_runs_are_byte_identical() {
    for mode in [StatMode::PerStream, StatMode::AggregateExact] {
        for sim_threads in [1u32, 4] {
            let tag = format!("{} threads={sim_threads}",
                              mode.label());
            let b = scenario(sim_threads, mode);

            // (a) cold session
            let mut cold = b.clone().build().unwrap();
            cold.run_to_idle().unwrap();
            let want = cold.snapshot().to_json();

            // (b) service: one worker, so the second submission must
            // recycle the first one's session
            let service = SimService::with_queue_bound(1, 4);
            let first = service.submit(b.clone()).unwrap()
                .wait().unwrap();
            let second = service.submit(b.clone()).unwrap()
                .wait().unwrap();
            assert_eq!(first.to_json(), want, "cold service [{tag}]");
            assert_eq!(second.to_json(), want,
                       "warm-reused run drifted [{tag}]");
            let stats = service.shutdown();
            assert_eq!(stats.cold_builds, 1, "[{tag}]");
            assert_eq!(stats.warm_hits, 1,
                       "second job missed the warm pool [{tag}]");

            // (c) batch (which itself rides on the service)
            for r in BatchRunner::new(2)
                .run(vec![b.clone(), b.clone()])
            {
                assert_eq!(r.unwrap().to_json(), want,
                           "batch run drifted [{tag}]");
            }
        }
    }
}

/// The bounded queue enforces backpressure: with parked workers the
/// bound is exact, `try_submit` fails fast with the typed
/// `QueueFull`, and nothing that was accepted is lost.
#[test]
fn queue_full_fires_at_the_configured_bound() {
    let job = || SimBuilder::preset("minimal").bench("l2_lat");
    let service = SimService::paused(1, 3);
    let accepted: Vec<_> = (0..3)
        .map(|_| service.try_submit(job()).unwrap())
        .collect();
    let err = service
        .try_submit(job())
        .err()
        .expect("the submission past the bound must be rejected");
    assert_eq!(err, ServiceError::QueueFull {
        lane: Priority::Batch,
        capacity: 3,
    });
    service.resume();
    // blocking submit rides out the backpressure instead
    let extra = service.submit(job()).unwrap();
    for h in accepted {
        h.wait().unwrap();
    }
    extra.wait().unwrap();
    let stats = service.shutdown();
    assert_eq!(stats.rejected_full, 1);
    assert_eq!(stats.jobs_run, 4);
    assert_eq!(stats.queue_peak, 3);
}

/// Shutdown under load drains without loss: every accepted job —
/// including ones no worker has even started — still runs and
/// replies before `shutdown` returns.
#[test]
fn shutdown_under_load_drains_without_loss() {
    let service = SimService::paused(2, 32);
    let handles: Vec<_> = (0..10)
        .map(|i| {
            let job = SimBuilder::preset("minimal")
                .bench("l2_lat")
                .label(&format!("job-{i}"));
            service.submit(job).unwrap()
        })
        .collect();
    // release the workers and immediately shut down: the queue is
    // still nearly full, so the drain guarantee does the work
    service.resume();
    let stats = service.shutdown();
    assert_eq!(stats.jobs_run, 10, "accepted jobs lost in shutdown");
    assert_eq!(stats.queue_depth, 0);
    for (i, h) in handles.into_iter().enumerate() {
        let snap = h.wait().unwrap_or_else(|e| {
            panic!("job {i} lost its reply: {e}")
        });
        assert_eq!(snap.label(), format!("job-{i}"));
    }
}

/// Per-job cycle budgets cancel with the partial snapshot attached,
/// and the cancelled job never disturbs its neighbours.
#[test]
fn cycle_budget_cancels_only_the_budgeted_job() {
    let service = SimService::with_queue_bound(2, 8);
    let capped = service
        .submit(SimJob::new(
            SimBuilder::preset("minimal").bench("l2_lat"))
            .cycle_budget(40))
        .unwrap();
    let free = service
        .submit(SimBuilder::preset("minimal").bench("l2_lat"))
        .unwrap();
    let err = capped.wait().unwrap_err();
    assert_eq!(err.kind(), "cycle_limit");
    let partial = err.partial_snapshot().expect("partial stats kept");
    assert!(partial.total_cycles() >= 40);
    let full = free.wait().unwrap();
    assert_eq!(full.kernels_done(), 4);
    let stats = service.shutdown();
    assert_eq!(stats.budget_stops, 1);
    assert_eq!(stats.job_errors, 1);
}

/// The `service` stats-JSON section matches its committed key golden
/// (`tests/golden/schema_service_keys.txt`) — the same drift
/// contract as the main document schema.
#[test]
fn service_section_matches_committed_golden() {
    let service = SimService::with_queue_bound(1, 2);
    service.submit(SimBuilder::preset("minimal").bench("l2_lat"))
        .unwrap()
        .wait()
        .unwrap();
    let section = service.shutdown().to_json();
    let mut got = vec![format!("schema_version={SCHEMA_VERSION}")];
    got.extend(top_level_keys(&section));
    let got = got.join("\n") + "\n";

    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/schema_service_keys.txt");
    let want = std::fs::read_to_string(&path).unwrap_or_else(|_| {
        panic!("missing committed golden {}", path.display())
    });
    assert_eq!(got, want,
               "service section schema drifted: rebless \
                tests/golden/schema_service_keys.txt only for an \
                intended change");
    // and the constant the writer advertises agrees
    assert_eq!(top_level_keys(&section),
               SERVICE_SECTION_KEYS.iter().map(|s| s.to_string())
                   .collect::<Vec<_>>());
}
