//! Determinism suite: the parallel sharded core/partition loop must be
//! **bit-identical** to the sequential path, and the sharded
//! double-buffered interconnect exchange must be bit-identical to the
//! PR-2 central exchange. The same workload runs at `--sim-threads`
//! 1/2/4/8 and the full exported stats JSON — every domain
//! (L1/L2/DRAM/icnt/power), every stream, per-kernel windows and
//! total cycle counts — must match byte for byte across thread counts,
//! for the paper's per-stream (`tip`) and `exact` modes. Clean mode is
//! pinned to one worker by design (its under-count is an inc-time
//! arrival-order artifact); the suite verifies the pin instead.
//!
//! The workloads are the paper's §5 microbenchmarks:
//! `benchmark_3_stream` at full size and `benchmark_1_stream` at the
//! suite-speed mini size (the full-size bench1 run lives in
//! `tests/end_to_end.rs`), plus `l2_lat` for the bypass/MSHR-merge
//! path and `idle_tail_mini` for the idle-skip active-set path.
//!
//! PR-6 adds an `idle_skip` axis: the idle-aware active-set loop
//! (default on) must be byte-identical to the always-tick loop
//! (`idle_skip 0`) across the same thread matrix.
//!
//! PR-9 adds a `fast_forward` axis: the event-horizon jump loop
//! (default on) must be byte-identical to ticking every cycle
//! (`fast_forward 0`) across the thread matrix, per mode, crossed
//! with `idle_skip` — and on the idle-tail workload it must execute
//! measurably fewer loop iterations than it simulates cycles
//! (asserted through `sim::profile::JumpStats`).

use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::stats::{export, StatMode};
use streamsim::workloads;

const THREAD_MATRIX: [u32; 4] = [1, 2, 4, 8];

/// Run `bench` and export the full stats document plus the exit log
/// (per-kernel per-stream window prints — merge-ordering bugs surface
/// here as count diffs even when totals accidentally agree).
fn run_fingerprint_on(bench: &str, preset: &str, mode: StatMode,
                      serialize: bool, threads: u32, sharded: bool,
                      idle_skip: bool, fast_forward: bool)
    -> String {
    let g = workloads::generate(bench).unwrap();
    let mut cfg = SimConfig::preset(preset).unwrap();
    cfg.stat_mode = mode;
    cfg.serialize_streams = serialize;
    cfg.sim_threads = threads;
    cfg.icnt_sharded = sharded;
    cfg.idle_skip = idle_skip;
    cfg.fast_forward = fast_forward;
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    let mut doc = export::to_json(mode.label(), sim.stats());
    doc.push('\n');
    for entry in &sim.stats().exit_log {
        doc.push_str(entry);
    }
    doc
}

fn run_fingerprint(bench: &str, preset: &str, mode: StatMode,
                   serialize: bool, threads: u32) -> String {
    run_fingerprint_on(bench, preset, mode, serialize, threads, true,
                       true, true)
}

fn assert_thread_matrix_identical(bench: &str, preset: &str,
                                  mode: StatMode, serialize: bool) {
    let reference =
        run_fingerprint(bench, preset, mode, serialize, THREAD_MATRIX[0]);
    for &t in &THREAD_MATRIX[1..] {
        let got = run_fingerprint(bench, preset, mode, serialize, t);
        assert_eq!(
            reference, got,
            "{bench}/{preset} mode={} serialize={serialize}: stats \
             diverged between --sim-threads {} and --sim-threads {t}",
            mode.label(), THREAD_MATRIX[0]);
    }
}

#[test]
fn per_stream_mode_bit_identical_across_thread_counts_bench1() {
    assert_thread_matrix_identical("bench1_mini", "sm7_titanv_mini",
                                   StatMode::PerStream, false);
}

#[test]
fn per_stream_mode_bit_identical_across_thread_counts_bench3() {
    assert_thread_matrix_identical("bench3", "sm7_titanv_mini",
                                   StatMode::PerStream, false);
}

#[test]
fn exact_mode_bit_identical_across_thread_counts_bench1() {
    assert_thread_matrix_identical("bench1_mini", "sm7_titanv_mini",
                                   StatMode::AggregateExact, false);
}

#[test]
fn exact_mode_bit_identical_across_thread_counts_bench3() {
    assert_thread_matrix_identical("bench3", "sm7_titanv_mini",
                                   StatMode::AggregateExact, false);
}

#[test]
fn serialized_gate_bit_identical_across_thread_counts() {
    // the paper's tip_serialized config through the same matrix
    assert_thread_matrix_identical("bench1_mini", "sm7_titanv_mini",
                                   StatMode::PerStream, true);
}

#[test]
fn l2_lat_bit_identical_across_thread_counts() {
    // bypass + cross-stream MSHR-merge path, single partition
    for mode in [StatMode::PerStream, StatMode::AggregateExact] {
        assert_thread_matrix_identical("l2_lat", "sm7_titanv_mini",
                                       mode, false);
    }
}

#[test]
fn sharded_exchange_bit_identical_to_central_exchange() {
    // the tentpole's semantic anchor: the sharded double-buffered
    // exchange reproduces the central crossbar byte for byte — same
    // entries, same global-id order, same drain cycles — at every
    // thread count, per mode and workload
    for (bench, mode) in [
        ("bench1_mini", StatMode::PerStream),
        ("bench3", StatMode::PerStream),
        ("bench3", StatMode::AggregateExact),
        ("l2_lat", StatMode::PerStream),
        ("bench1_mini", StatMode::AggregateBuggy),
    ] {
        let central = run_fingerprint_on(bench, "sm7_titanv_mini",
                                         mode, false, 1, false, true,
                                         true);
        for &t in &THREAD_MATRIX {
            let sharded = run_fingerprint_on(
                bench, "sm7_titanv_mini", mode, false, t, true, true,
                true);
            assert_eq!(
                central, sharded,
                "{bench} mode={}: sharded exchange at --sim-threads \
                 {t} diverged from the central exchange",
                mode.label());
        }
    }
}

#[test]
fn idle_skip_bit_identical_to_always_tick() {
    // the PR-6 tentpole's semantic anchor: the idle-aware active set
    // (sleep/wake + ledger dispatch + empty-swap early-out) must be a
    // pure scheduling optimization — stats, kernel windows and exit
    // logs byte-identical to ticking every component every cycle, at
    // every thread count, sharded and central, per mode and workload.
    // idle_tail_mini is the adversarial case: most components sleep
    // for most of the run.
    for (bench, mode) in [
        ("bench1_mini", StatMode::PerStream),
        ("bench3", StatMode::PerStream),
        ("bench3", StatMode::AggregateExact),
        ("idle_tail_mini", StatMode::PerStream),
        ("bench1_mini", StatMode::AggregateBuggy),
    ] {
        let baseline = run_fingerprint_on(
            bench, "sm7_titanv_mini", mode, false, 1, true, false,
            true);
        for &t in &THREAD_MATRIX {
            for skip in [false, true] {
                let got = run_fingerprint_on(
                    bench, "sm7_titanv_mini", mode, false, t, true,
                    skip, true);
                assert_eq!(
                    baseline, got,
                    "{bench} mode={}: idle_skip={} at --sim-threads \
                     {t} diverged from the always-tick baseline",
                    mode.label(), skip as u8);
            }
        }
        // central-exchange spot check: the inbox delivery wakes
        let central = run_fingerprint_on(
            bench, "sm7_titanv_mini", mode, false, 1, false, true,
            true);
        assert_eq!(baseline, central,
                   "{bench} mode={}: central idle_skip run diverged",
                   mode.label());
    }
}

#[test]
fn fast_forward_bit_identical_to_always_tick() {
    // the PR-9 tentpole's semantic anchor: multi-cycle clock jumps
    // over provably-quiet stretches must be a pure scheduling
    // optimization — stats, kernel windows and exit logs
    // byte-identical to ticking every cycle, across the full
    // --sim-threads x mode x idle_skip matrix. idle_tail_mini is the
    // adversarial case: its straggler tail is one long quiet stretch.
    for (bench, mode) in [
        ("bench1_mini", StatMode::PerStream),
        ("bench3", StatMode::PerStream),
        ("bench3", StatMode::AggregateExact),
        ("idle_tail_mini", StatMode::PerStream),
        ("l2_lat", StatMode::AggregateExact),
    ] {
        let baseline = run_fingerprint_on(
            bench, "sm7_titanv_mini", mode, false, 1, true, true,
            false);
        for &t in &THREAD_MATRIX {
            for skip in [false, true] {
                for ff in [false, true] {
                    let got = run_fingerprint_on(
                        bench, "sm7_titanv_mini", mode, false, t,
                        true, skip, ff);
                    assert_eq!(
                        baseline, got,
                        "{bench} mode={}: fast_forward={} \
                         idle_skip={} at --sim-threads {t} diverged \
                         from the always-tick baseline",
                        mode.label(), ff as u8, skip as u8);
                }
            }
        }
    }
}

#[test]
fn fast_forward_jumps_over_the_idle_tail() {
    // the perf acceptance bar: on the straggler-tail workload the
    // jump loop must execute measurably fewer loop iterations than
    // it simulates cycles, and every simulated cycle must be
    // accounted for as either a real tick or a skipped one
    let run = |ff: bool| {
        let g = workloads::generate("idle_tail_mini").unwrap();
        let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        cfg.fast_forward = ff;
        let mut sim = GpuSim::new(cfg).unwrap();
        sim.enqueue_workload(&g.workload).unwrap();
        sim.run().unwrap();
        let total = sim.stats().total_cycles;
        let j = sim.jump_stats().clone();
        (total, j)
    };
    let (base_total, base_jumps) = run(false);
    assert_eq!(base_jumps.jumps, 0,
               "fast_forward 0 must never jump");
    assert_eq!(base_jumps.skipped_cycles, 0);
    assert_eq!(base_jumps.ticks, base_total,
               "always-tick runs one iteration per cycle");
    let (total, jumps) = run(true);
    assert_eq!(total, base_total,
               "fast_forward changed the simulated cycle count");
    assert_eq!(jumps.ticks + jumps.skipped_cycles, total,
               "every cycle must be a tick or a skip");
    assert!(jumps.jumps > 0,
            "idle tail produced no jumps: {jumps:?}");
    assert!(jumps.ticks < total,
            "jump loop iterations ({}) not measurably fewer than \
             simulated cycles ({total})", jumps.ticks);
    assert_eq!(jumps.histogram.iter().sum::<u64>(), jumps.jumps,
               "histogram buckets must sum to the jump count");
}

#[test]
fn clean_mode_ignores_thread_flag_and_stays_identical() {
    // clean is pinned to one worker regardless of the flag — so its
    // output is trivially identical across requested counts, and the
    // pin itself is asserted
    let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    cfg.stat_mode = StatMode::AggregateBuggy;
    cfg.sim_threads = 8;
    assert_eq!(GpuSim::new(cfg).unwrap().threads(), 1);
    assert_thread_matrix_identical("bench1_mini", "sm7_titanv_mini",
                                   StatMode::AggregateBuggy, false);
}

#[test]
fn parallel_tip_sum_still_equals_exact() {
    // cross-mode anchor at 4 workers: Σ per-stream (tip) == exact —
    // catches a bug that shifts tip and exact identically across
    // thread counts but breaks attribution
    let run = |mode: StatMode| {
        let g = workloads::generate("bench1_mini").unwrap();
        let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
        cfg.stat_mode = mode;
        cfg.sim_threads = 4;
        let mut sim = GpuSim::new(cfg).unwrap();
        sim.enqueue_workload(&g.workload).unwrap();
        sim.run().unwrap();
        (sim.stats().l1().total_table(), sim.stats().l2().total_table())
    };
    let (tip_l1, tip_l2) = run(StatMode::PerStream);
    let (exact_l1, exact_l2) = run(StatMode::AggregateExact);
    assert_eq!(tip_l1, exact_l1);
    assert_eq!(tip_l2, exact_l2);
}
