//! The idle-skip safety contract (PR-6 satellite): a component the
//! active set would skip must be one whose tick is a **no-op** — no
//! stat deltas, no queue movement, no retirements, no observable state
//! change at all. This suite pins that contract at the component level
//! on randomized scenarios (proptest-lite), and pins the end-to-end
//! consequence: `idle_skip` on/off is byte-identical on random
//! multi-stream workloads.
//!
//! PR-9 adds the stronger event-horizon (`next_event_in`) contract
//! (see `streamsim::activity` module docs): for any `j` no larger
//! than a component's reported horizon, jumping the clock by `j` and
//! ticking once must be byte-identical to ticking through every
//! intermediate cycle — pinned here by driving identical random
//! scenarios with and without horizon-bounded jumps and comparing
//! full `Debug` state.

use streamsim::config::SimConfig;
use streamsim::core::SimtCore;
use streamsim::mem::{FetchIdAlloc, MemPartition};
use streamsim::stats::{PartitionSink, StatDomain, StatMode,
                       StatsEngine};
use streamsim::trace::{Dim3, KernelTrace, MemInstr, MemSpace, TbTrace,
                       TraceOp, Workload};
use streamsim::util::proptest_lite::{default_cases, run_cases, Gen};

fn cfg() -> SimConfig {
    let mut c = SimConfig::preset("sm7_titanv_mini").unwrap();
    // one partition so the manual loop below routes everything there
    c.num_l2_partitions = 1;
    c
}

/// A random little TB: 1-2 warps, each a few ALU ops and global
/// accesses (mixed reads/writes/bypasses over distinct lines).
fn random_tb(g: &mut Gen, salt: u64) -> TbTrace {
    let warps = (0..1 + g.index(2))
        .map(|w| {
            let mut ops = vec![TraceOp::Alu {
                count: 1 + g.below(3) as u32 }];
            for i in 0..1 + g.index(3) {
                let line = salt * 64 + w as u64 * 16 + i as u64;
                ops.push(TraceOp::Mem(MemInstr {
                    pc: i as u32,
                    space: MemSpace::Global,
                    is_write: g.chance(0.3),
                    size: 4,
                    base_addr: 0x7f40_0000_0000 + line * 128,
                    stride: 4,
                    active_mask: if g.chance(0.5) { u32::MAX } else { 1 },
                    l1_bypass: g.chance(0.25),
                }));
                if g.chance(0.5) {
                    ops.push(TraceOp::Alu { count: 1 });
                }
            }
            ops
        })
        .collect();
    TbTrace { warps }
}

/// Every stat sink a core/partition tick can write through must still
/// be zero in `probe` (fresh engine handed to the tick under test).
fn assert_probe_untouched(probe: &StatsEngine) {
    assert_eq!(probe.cache(StatDomain::L1).total_table().total(), 0);
    assert_eq!(probe.cache(StatDomain::L1).total_fail_table().total(),
               0);
    assert_eq!(probe.cache(StatDomain::L2).total_table().total(), 0);
    assert_eq!(probe.cache(StatDomain::L2).total_fail_table().total(),
               0);
    assert_eq!(probe.domain_total(StatDomain::Dram), 0);
    assert_eq!(probe.domain_total(StatDomain::Icnt), 0);
    assert_eq!(probe.domain_total(StatDomain::Power), 0);
}

/// The component-level contract: whenever `activity().is_idle()`
/// reports a core or partition as skippable, actually ticking it (with
/// a fresh stats engine) changes nothing — and `is_idle` agrees with
/// `busy()` exactly (for partitions: `busy()` plus undrained
/// responses, which the clock loop always drains before the sleep
/// decision).
#[test]
fn idle_component_tick_is_a_noop() {
    run_cases("idle_tick_noop", 0x1d1e_5c1b, default_cases(), |g| {
        let cfg = cfg();
        let mut core = SimtCore::new(0, &cfg);
        let mut part = MemPartition::new(0, &cfg);
        let mut engine = StatsEngine::new(StatMode::PerStream);
        let mut ids = FetchIdAlloc::default();
        let n_tbs = 1 + g.index(4);
        let tbs: Vec<(u64, TbTrace)> = (0..n_tbs)
            .map(|i| {
                let stream = g.below(3);
                (stream, random_tb(g, i as u64))
            })
            .collect();
        let mut next_tb = 0;
        let mut now = 0u64;
        let mut guard = 0;
        while next_tb < tbs.len() || core.busy() || part.busy() {
            guard += 1;
            assert!(guard < 50_000, "scenario deadlocked");
            // stochastic dispatch — leaves idle gaps before, between
            // and after TBs, which is exactly what the probe wants
            if next_tb < tbs.len() && g.chance(0.2) {
                let (stream, tb) = &tbs[next_tb];
                if core.can_accept(tb.warps.len() as u32) {
                    let slot = engine.intern_stream(*stream);
                    core.accept_tb(1, *stream, slot, next_tb, tb);
                    next_tb += 1;
                }
            }

            // core: is_idle ⟺ !busy, and an idle tick is a no-op
            assert_eq!(core.activity().is_idle(), !core.busy());
            if core.activity().is_idle() {
                let before = core.activity();
                let mut probe = StatsEngine::new(StatMode::PerStream);
                core.cycle(now, &mut probe, &mut ids);
                assert!(core.drain_to_icnt().is_empty(),
                        "idle core emitted a fetch");
                assert!(core.take_finished().is_empty(),
                        "idle core retired a TB");
                assert_eq!(core.activity(), before,
                           "idle core tick moved state");
                assert!(!core.busy());
                assert_probe_untouched(&probe);
            }
            core.cycle(now, &mut engine, &mut ids);
            for f in core.drain_to_icnt() {
                part.push_request(f);
            }

            // partition: is_idle ⟺ !busy (outgoing is drained every
            // cycle below, mirroring the clock loop), and an idle
            // tick is a no-op
            assert_eq!(part.activity().is_idle(), !part.busy());
            if part.activity().is_idle() {
                let before = part.activity();
                let mut probe = StatsEngine::new(StatMode::PerStream);
                part.cycle(now,
                           &mut PartitionSink::Central(&mut probe));
                assert!(part.drain_responses().is_empty(),
                        "idle partition emitted a response");
                assert_eq!(part.activity(), before,
                           "idle partition tick moved state");
                assert!(!part.busy());
                assert_probe_untouched(&probe);
            }
            part.cycle(now, &mut PartitionSink::Central(&mut engine));
            for f in part.drain_responses() {
                core.receive_response(f, now);
            }
            now += 1;
        }
        // the scenario must have exercised real work
        assert!(engine.cache(StatDomain::L1).total_table().total() > 0
                || engine.cache(StatDomain::L2).total_table()
                    .total() > 0,
                "degenerate scenario: no memory traffic at all");
    });
}

/// The `next_event_in` jump contract (PR-9): drive the same random
/// scenario once tick-by-tick and once with clock jumps of `j <= h`
/// cycles (where `h` is the minimum of the components' reported
/// horizons, clamped at the next scheduled dispatch exactly like the
/// clock loop's launch/dispatch pin). The two runs must end with
/// byte-identical component state (full `Debug` formatting),
/// identical stats and the same simulated-cycle count — while the
/// jumping run executes strictly fewer loop iterations.
#[test]
fn horizon_jumps_are_byte_identical_to_always_ticking() {
    let cases = (default_cases() / 4).max(8);
    run_cases("next_event_horizon", 0xfa57_f0a4, cases, |g| {
        let n_tbs = 1 + g.index(4);
        let mut at = 0u64;
        let tbs: Vec<(u64, u64, TbTrace)> = (0..n_tbs)
            .map(|i| {
                // long quiet gaps between dispatches are the point:
                // they are what the jump loop must leap over
                if i > 0 {
                    at += 64 + g.below(256);
                }
                let stream = g.below(3);
                (at, stream, random_tb(g, i as u64))
            })
            .collect();
        let run = |jumping: bool| -> (String, u64, u64) {
            let cfg = cfg();
            let mut core = SimtCore::new(0, &cfg);
            let mut part = MemPartition::new(0, &cfg);
            let mut engine = StatsEngine::new(StatMode::PerStream);
            let mut ids = FetchIdAlloc::default();
            let mut next_tb = 0usize;
            let mut now = 0u64;
            let mut iters = 0u64;
            let mut retired = 0usize;
            let mut guard = 0;
            while next_tb < tbs.len() || core.busy() || part.busy() {
                guard += 1;
                assert!(guard < 200_000, "scenario deadlocked");
                if next_tb < tbs.len() && now >= tbs[next_tb].0 {
                    let (_, stream, tb) = &tbs[next_tb];
                    if core.can_accept(tb.warps.len() as u32) {
                        let slot = engine.intern_stream(*stream);
                        core.accept_tb(1, *stream, slot, next_tb, tb);
                        next_tb += 1;
                    }
                }
                core.cycle(now, &mut engine, &mut ids);
                retired += core.take_finished().len();
                for f in core.drain_to_icnt() {
                    part.push_request(f);
                }
                part.cycle(now,
                           &mut PartitionSink::Central(&mut engine));
                for f in part.drain_responses() {
                    core.receive_response(f, now);
                }
                iters += 1;
                if !jumping {
                    now += 1;
                    continue;
                }
                let mut h = core
                    .next_event_in(now)
                    .min(part.next_event_in(now));
                // the dispatch pin: a TB due (or overdue) bounds the
                // jump exactly like the clock loop's launch/dispatch
                // clamp in GpuSim::global_horizon
                if next_tb < tbs.len() {
                    let due = tbs[next_tb].0;
                    h = if now >= due { 1 } else { h.min(due - now) };
                }
                if h == u64::MAX {
                    h = 1; // drain-out: nothing pending anywhere
                }
                // any j <= h must be equivalent, not just j == h:
                // land on deterministic interior cycles too
                let j = 1 + now
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .rotate_right(17)
                    % h;
                now += j;
            }
            let state = format!(
                "{core:?}\n{part:?}\n{:?}|{:?}|{:?}|l1={} l1f={} \
                 l2={} l2f={} retired={retired}",
                engine.per_stream(StatDomain::Dram),
                engine.per_stream(StatDomain::Icnt),
                engine.per_stream(StatDomain::Power),
                engine.cache(StatDomain::L1).total_table().total(),
                engine.cache(StatDomain::L1).total_fail_table()
                    .total(),
                engine.cache(StatDomain::L2).total_table().total(),
                engine.cache(StatDomain::L2).total_fail_table()
                    .total());
            (state, now, iters)
        };
        let (tick_state, tick_now, tick_iters) = run(false);
        let (jump_state, jump_now, jump_iters) = run(true);
        assert_eq!(tick_iters, tick_now,
                   "always-tick must run one iteration per cycle");
        assert_eq!(jump_state, tick_state,
                   "horizon-jumped run diverged from always-tick");
        assert_eq!(jump_now, tick_now,
                   "jumped run simulated a different cycle count");
        assert!(jump_iters < tick_iters,
                "horizon jumps saved no iterations \
                 (iters={jump_iters}, cycles={jump_now})");
    });
}

/// Random multi-stream kernel over a few one-warp TBs.
fn random_kernel(g: &mut Gen, uid: u32, stream: u64) -> KernelTrace {
    let n_tbs = 1 + g.index(6) as u32;
    let tbs = (0..n_tbs)
        .map(|tb| random_tb(g, (uid as u64) << 16 | tb as u64))
        .collect::<Vec<_>>();
    let max_warps =
        tbs.iter().map(|t| t.warps.len()).max().unwrap() as u32;
    KernelTrace {
        name: format!("rand_k{uid}"),
        kernel_id: uid,
        grid: Dim3::linear(n_tbs),
        block: Dim3::linear(max_warps * 32),
        stream_id: stream,
        shared_mem_bytes: 0,
        tbs: tbs
            .into_iter()
            .map(|mut t| {
                // pad every TB to the kernel's warp count so the
                // trace validates (grid-uniform block shape)
                while (t.warps.len() as u32) * 32 < max_warps * 32 {
                    t.warps.push(vec![TraceOp::Alu { count: 1 }]);
                }
                t
            })
            .collect(),
    }
}

/// End-to-end consequence on whole random workloads: the active-set
/// loop produces byte-identical documents with `idle_skip` on and off,
/// sequential and parallel.
#[test]
fn idle_skip_equivalence_on_random_multi_stream_workloads() {
    use streamsim::api::SimBuilder;
    // fewer cases than the component test — each runs 2 modes × 2
    // thread counts of a whole simulation
    let cases = (default_cases() / 8).max(4);
    run_cases("idle_skip_equiv", 0x5ca1_ab1e, cases, |g| {
        let n_kernels = 2 + g.index(3);
        let kernels = (0..n_kernels)
            .map(|i| random_kernel(g, i as u32 + 1, g.below(3)))
            .collect::<Vec<_>>();
        let workload = Workload { kernels, memcpys: Vec::new() };
        workload.validate().unwrap();
        let run = |skip: bool, threads: u32| {
            let mut s = SimBuilder::preset("sm7_titanv_mini")
                .workload(workload.clone())
                .sim_threads(threads)
                .idle_skip(skip)
                .build()
                .unwrap();
            s.run_to_idle().unwrap();
            s.into_snapshot().to_json()
        };
        let baseline = run(false, 1);
        for threads in [1, 4] {
            for skip in [false, true] {
                assert_eq!(baseline, run(skip, threads),
                           "idle_skip={skip} threads={threads} \
                            diverged");
            }
        }
    });
}
