//! Integration suite for the `streamsim::server` wire protocol:
//! loopback TCP with concurrent mixed-priority clients whose result
//! documents byte-agree with direct `SimSession` runs, streaming
//! deltas that sum to the final totals, cooperative cancellation,
//! memo-hit byte-identity, graceful drain with unsolicited result
//! flushing, and the `server` stats-JSON section's key golden.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;

use streamsim::api::{Priority, SCHEMA_VERSION,
                     SERVER_SECTION_KEYS};
use streamsim::server::json::{self, Json};
use streamsim::server::proto::{JobSpec, Request, Response,
                               PROTO_VERSION};
use streamsim::server::{serve_io, ServerConfig, SimServer};
use streamsim::stats::StatDomain;

/// A blocking line-frame client over loopback TCP.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn send(&mut self, req: &Request) {
        writeln!(self.writer, "{}", req.to_json()).unwrap();
        self.writer.flush().unwrap();
    }

    /// Read one response frame; panics on EOF.
    fn recv(&mut self) -> Response {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection early");
        Response::parse(line.trim_end()).unwrap()
    }

    /// Read until EOF, returning every remaining frame.
    fn drain(mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let mut line = String::new();
        while self.reader.read_line(&mut line).unwrap() > 0 {
            out.push(Response::parse(line.trim_end()).unwrap());
            line.clear();
        }
        out
    }
}

fn spawn_server(
    config: ServerConfig,
) -> (SocketAddr, thread::JoinHandle<String>) {
    let server = SimServer::bind("127.0.0.1:0", config).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = thread::spawn(move || server.serve().unwrap());
    (addr, handle)
}

fn spec_with_latency(l2_latency: u32, lane: Priority) -> JobSpec {
    let mut overrides = BTreeMap::new();
    overrides.insert("l2_latency".to_string(),
                     l2_latency.to_string());
    JobSpec {
        preset: "minimal".to_string(),
        overrides,
        priority: lane,
        ..JobSpec::bench("l2_lat")
    }
}

fn direct_doc(spec: &JobSpec) -> String {
    let mut session = spec.to_builder().build().unwrap();
    session.run_to_idle().unwrap();
    session.into_snapshot().to_json()
}

/// N concurrent clients, mixed lanes, distinct scenarios: every
/// wire-delivered document is byte-identical to a direct
/// `SimSession` run of the same spec, and the final stats document
/// accounts for every connection and both lanes.
#[test]
fn concurrent_tcp_clients_byte_agree_with_direct_sessions() {
    let (addr, server) = spawn_server(ServerConfig {
        threads: 2,
        queue_bound: 16,
        memo_capacity: 0, // cold runs only: memo has its own test
        ..ServerConfig::default()
    });
    let lanes = [Priority::Interactive, Priority::Batch,
                 Priority::Interactive];
    let clients: Vec<_> = lanes
        .iter()
        .enumerate()
        .map(|(i, lane)| {
            let spec =
                spec_with_latency(20 + 10 * i as u32, *lane);
            thread::spawn(move || {
                let mut c = Client::connect(addr);
                c.send(&Request::Hello {
                    proto_version: PROTO_VERSION,
                });
                assert!(matches!(c.recv(),
                                 Response::HelloOk { .. }));
                c.send(&Request::Submit { spec: spec.clone() });
                let Response::Submitted { job_id, memo_hit: false } =
                    c.recv()
                else {
                    panic!("expected submitted")
                };
                c.send(&Request::Wait { job_id });
                let Response::JobDone {
                    job_id: done_id,
                    memo_hit: false,
                    doc,
                } = c.recv()
                else {
                    panic!("expected job_done")
                };
                assert_eq!(done_id, job_id);
                assert_eq!(doc, direct_doc(&spec),
                           "wire document drifted from the direct \
                            session run");
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    let mut shutter = Client::connect(addr);
    shutter.send(&Request::Shutdown);
    assert!(matches!(shutter.recv(), Response::Goodbye { .. }));
    let final_doc = server.join().unwrap();
    let v = json::parse(&final_doc).unwrap();
    let server_obj = v.get("server").expect("server section");
    assert_eq!(server_obj.get("connections").unwrap().as_u64(),
               Some(4));
    assert_eq!(server_obj.get("submits").unwrap().as_u64(),
               Some(3));
    let service_obj = v.get("service").expect("service section");
    assert_eq!(
        service_obj.get("interactive_jobs").unwrap().as_u64(),
        Some(2));
    assert_eq!(service_obj.get("batch_jobs").unwrap().as_u64(),
               Some(1));
}

/// A memo-eligible spec submitted twice: the second submission is a
/// declared hit and replays byte-identical document bytes, with the
/// hit/miss counters surfacing in the final stats document.
#[test]
fn memo_hit_replays_byte_identical_documents() {
    let requests = [
        Request::Hello { proto_version: PROTO_VERSION },
        Request::Submit { spec: JobSpec::bench("l2_lat") },
        Request::Wait { job_id: 1 },
        Request::Submit { spec: JobSpec::bench("l2_lat") },
        Request::Wait { job_id: 2 },
        Request::Shutdown,
    ];
    let mut input = String::new();
    for r in &requests {
        input.push_str(&r.to_json());
        input.push('\n');
    }
    let mut out: Vec<u8> = Vec::new();
    let final_doc = serve_io(
        ServerConfig::default(),
        Cursor::new(input),
        &mut out,
    )
    .unwrap();
    let frames: Vec<Response> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Response::parse(l).unwrap())
        .collect();
    assert_eq!(frames.len(), 6);
    assert_eq!(frames[1], Response::Submitted {
        job_id: 1,
        memo_hit: false,
    });
    let Response::JobDone { memo_hit: false, doc: ref cold, .. } =
        frames[2]
    else {
        panic!("expected cold job_done, got {:?}", frames[2]);
    };
    assert_eq!(frames[3], Response::Submitted {
        job_id: 2,
        memo_hit: true,
    });
    let Response::JobDone { memo_hit: true, doc: ref warm, .. } =
        frames[4]
    else {
        panic!("expected memo job_done, got {:?}", frames[4]);
    };
    assert_eq!(warm, cold, "memo replay drifted from the cold run");
    let v = json::parse(&final_doc).unwrap();
    let server_obj = v.get("server").unwrap();
    assert_eq!(server_obj.get("memo_hits").unwrap().as_u64(),
               Some(1));
    assert_eq!(server_obj.get("memo_misses").unwrap().as_u64(),
               Some(1));
    // the memoized second job never reached the service
    assert_eq!(
        v.get("service").unwrap().get("jobs_run").unwrap().as_u64(),
        Some(1));
}

/// `stream` deltas are exact increments: summed per domain and
/// stream they reproduce the per-stream totals of a direct run.
#[test]
fn stream_deltas_sum_to_the_final_totals() {
    let spec = JobSpec::bench("l2_lat");
    let requests = [
        Request::Stream { spec: spec.clone(), interval: 32 },
        Request::Shutdown,
    ];
    let mut input = String::new();
    for r in &requests {
        input.push_str(&r.to_json());
        input.push('\n');
    }
    let mut out: Vec<u8> = Vec::new();
    serve_io(ServerConfig::default(), Cursor::new(input), &mut out)
        .unwrap();
    let frames: Vec<Response> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Response::parse(l).unwrap())
        .collect();
    let mut summed: BTreeMap<(String, String), u64> =
        BTreeMap::new();
    let mut deltas = 0u64;
    let mut last_seq = 0u64;
    let mut done_doc = None;
    for f in &frames {
        match f {
            Response::Delta { seq, domains, .. } => {
                deltas += 1;
                assert_eq!(*seq, last_seq + 1,
                           "delta frames out of order");
                last_seq = *seq;
                for (domain, cells) in domains {
                    for (stream, n) in cells {
                        assert!(*n > 0,
                                "zero-delta cells must be omitted");
                        *summed
                            .entry((domain.clone(), stream.clone()))
                            .or_default() += n;
                    }
                }
            }
            Response::JobDone { doc, .. } => {
                done_doc = Some(doc.clone());
            }
            Response::Goodbye { .. } => {}
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(deltas >= 2, "expected several deltas, got {deltas}");
    let done_doc = done_doc.expect("missing terminal job_done");
    // ground truth: a direct session of the same spec
    let mut session = spec.to_builder().build().unwrap();
    session.run_to_idle().unwrap();
    let snap = session.snapshot();
    for d in StatDomain::ALL {
        for (stream, want) in snap.per_stream(d) {
            let got = summed
                .get(&(d.name().to_string(), stream.to_string()))
                .copied()
                .unwrap_or(0);
            assert_eq!(got, want,
                       "summed {} deltas drifted for stream \
                        {stream}", d.name());
        }
    }
    assert_eq!(done_doc, snap.to_json(),
               "stream terminal document drifted from the direct \
                run");
}

/// Fast-forward clock jumps (`fast_forward`, default-on) must be
/// clamped at the `stream` delta boundary: with a long-latency spec
/// whose provably-quiet stretches dwarf a small interval, every
/// non-terminal delta frame still lands on its exact interval cycle
/// (an unclamped jump would overshoot the boundary and emit frames
/// at jump-dependent cycles).
#[test]
fn stream_deltas_land_on_exact_interval_boundaries() {
    const INTERVAL: u64 = 16;
    // l2_latency 400 on the minimal preset: each miss parks in a
    // timed queue for hundreds of cycles, so the event horizon
    // repeatedly exceeds the interval by an order of magnitude
    let mut overrides = BTreeMap::new();
    overrides.insert("l2_latency".to_string(), "400".to_string());
    let spec = JobSpec {
        preset: "minimal".to_string(),
        overrides,
        ..JobSpec::bench("l2_lat")
    };
    let requests = [
        Request::Stream { spec, interval: INTERVAL },
        Request::Shutdown,
    ];
    let mut input = String::new();
    for r in &requests {
        input.push_str(&r.to_json());
        input.push('\n');
    }
    let mut out: Vec<u8> = Vec::new();
    serve_io(ServerConfig::default(), Cursor::new(input), &mut out)
        .unwrap();
    let frames: Vec<Response> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Response::parse(l).unwrap())
        .collect();
    let deltas: Vec<(u64, u64)> = frames
        .iter()
        .filter_map(|f| match f {
            Response::Delta { cycles, delta_cycles, .. } => {
                Some((*cycles, *delta_cycles))
            }
            _ => None,
        })
        .collect();
    assert!(deltas.len() >= 4,
            "long-latency run should span several intervals, got \
             {deltas:?}");
    // every frame except the terminal (idle-triggered) one sits on
    // an exact interval boundary with an exact interval-wide window
    for (cycles, delta_cycles) in
        &deltas[..deltas.len() - 1]
    {
        assert_eq!(cycles % INTERVAL, 0,
                   "delta frame off its interval boundary: \
                    cycles={cycles} interval={INTERVAL}");
        assert_eq!(*delta_cycles, INTERVAL,
                   "delta window drifted: {delta_cycles}");
    }
}

/// Cancelling a queued job over the wire reports `cancel_ok` and a
/// terminal `job_failed` with the stable `cancelled` kind.
#[test]
fn cancel_over_the_wire_reports_the_cancelled_kind() {
    let (addr, server) = spawn_server(ServerConfig {
        threads: 1, // one worker: the second job stays queued
        queue_bound: 8,
        memo_capacity: 0,
        ..ServerConfig::default()
    });
    let mut c = Client::connect(addr);
    // a longer job occupies the single worker (slowed further so the
    // cancel always lands while the victim is still queued)...
    let mut slow = BTreeMap::new();
    slow.insert("l2_latency".to_string(), "400".to_string());
    c.send(&Request::Submit {
        spec: JobSpec {
            overrides: slow,
            ..JobSpec::bench("bench3")
        },
    });
    let Response::Submitted { job_id: busy, .. } = c.recv() else {
        panic!("expected submitted")
    };
    // ...so this one is still queued when the cancel lands
    c.send(&Request::Submit { spec: JobSpec::bench("l2_lat") });
    let Response::Submitted { job_id: doomed, .. } = c.recv()
    else {
        panic!("expected submitted")
    };
    c.send(&Request::Cancel { job_id: doomed });
    assert_eq!(c.recv(), Response::CancelOk { job_id: doomed });
    c.send(&Request::Wait { job_id: doomed });
    let Response::JobFailed { kind, partial, .. } = c.recv() else {
        panic!("expected job_failed for the cancelled job")
    };
    assert_eq!(kind, "cancelled");
    assert!(partial.is_none(),
            "a never-started job has no partial document");
    // cancelling it again is an error, not a hang
    c.send(&Request::Cancel { job_id: doomed });
    let Response::Error { code, .. } = c.recv() else {
        panic!("expected error for the consumed job")
    };
    assert_eq!(code, "unknown_job");
    c.send(&Request::Wait { job_id: busy });
    assert!(matches!(c.recv(), Response::JobDone { .. }));
    c.send(&Request::Shutdown);
    assert!(matches!(c.recv(), Response::Goodbye { .. }));
    let final_doc = server.join().unwrap();
    let v = json::parse(&final_doc).unwrap();
    assert_eq!(
        v.get("service").unwrap().get("cancelled").unwrap()
            .as_u64(),
        Some(1));
}

/// Graceful drain: a `shutdown` from one client makes another
/// connection's pending result arrive as an unsolicited frame,
/// followed by a `goodbye`, before the server exits.
#[test]
fn drain_flushes_pending_results_to_other_connections() {
    let (addr, server) = spawn_server(ServerConfig {
        threads: 2,
        queue_bound: 8,
        memo_capacity: 0,
        ..ServerConfig::default()
    });
    let mut waiter = Client::connect(addr);
    waiter.send(&Request::Submit {
        spec: JobSpec::bench("l2_lat"),
    });
    let Response::Submitted { job_id, .. } = waiter.recv() else {
        panic!("expected submitted")
    };
    // a different connection shuts the server down
    let mut shutter = Client::connect(addr);
    shutter.send(&Request::Shutdown);
    assert!(matches!(shutter.recv(), Response::Goodbye { .. }));
    // the waiter never asked — the drain delivers anyway
    let frames = waiter.drain();
    assert_eq!(frames.len(), 2, "{frames:?}");
    let Response::JobDone { job_id: done_id, .. } = &frames[0]
    else {
        panic!("expected the flushed result, got {:?}", frames[0]);
    };
    assert_eq!(*done_id, job_id);
    assert!(matches!(frames[1], Response::Goodbye { .. }));
    let final_doc = server.join().unwrap();
    assert!(final_doc.contains("\"server\":{"));
}

/// A submission past the per-lane bound surfaces as the typed
/// `queue_full` error frame naming the lane, not a hang.
#[test]
fn lane_backpressure_reaches_the_wire() {
    let (addr, server) = spawn_server(ServerConfig {
        threads: 1,
        queue_bound: 1,
        memo_capacity: 0,
        ..ServerConfig::default()
    });
    let mut c = Client::connect(addr);
    let batch = JobSpec {
        priority: Priority::Batch,
        ..JobSpec::bench("bench3")
    };
    // worker + full batch lane; the exact rejection point depends on
    // how fast the worker dequeues, so push until the error frame
    let mut rejected = None;
    for _ in 0..8 {
        c.send(&Request::Submit { spec: batch.clone() });
        match c.recv() {
            Response::Submitted { .. } => continue,
            Response::Error { code, message } => {
                rejected = Some((code, message));
                break;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    let (code, message) =
        rejected.expect("the bounded lane never rejected");
    assert_eq!(code, "queue_full");
    assert!(message.contains("batch lane full"), "{message}");
    c.send(&Request::Shutdown);
    // drain: every accepted job still replies, then the goodbye
    let frames = c.drain();
    assert!(matches!(frames.last(),
                     Some(Response::Goodbye { .. })),
            "{frames:?}");
    for f in &frames[..frames.len() - 1] {
        assert!(matches!(f, Response::JobDone { .. }), "{f:?}");
    }
    server.join().unwrap();
}

/// The `server` stats-JSON section matches its committed key golden
/// (`tests/golden/schema_server_keys.txt`) — the same drift
/// contract as the `service` section and the main document schema.
#[test]
fn server_section_matches_committed_golden() {
    let input = format!(
        "{}\n{}\n",
        Request::Hello { proto_version: PROTO_VERSION }.to_json(),
        Request::Shutdown.to_json());
    let mut out: Vec<u8> = Vec::new();
    let final_doc = serve_io(
        ServerConfig::default(),
        Cursor::new(input),
        &mut out,
    )
    .unwrap();
    let v = json::parse(&final_doc).unwrap();
    let Some(Json::Obj(fields)) = v.get("server") else {
        panic!("missing server section in {final_doc}");
    };
    let mut got = vec![format!("schema_version={SCHEMA_VERSION}")];
    got.extend(fields.iter().map(|(k, _)| k.clone()));
    let got = got.join("\n") + "\n";

    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/schema_server_keys.txt");
    let want = std::fs::read_to_string(&path).unwrap_or_else(|_| {
        panic!("missing committed golden {}", path.display())
    });
    assert_eq!(got, want,
               "server section schema drifted: rebless \
                tests/golden/schema_server_keys.txt only for an \
                intended change");
    // and the constant the writer advertises agrees
    assert_eq!(
        fields.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
        SERVER_SECTION_KEYS.to_vec());
}
