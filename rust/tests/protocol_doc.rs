//! Protocol-spec drift guard: `docs/PROTOCOL.md` is the normative
//! wire spec, and this test keeps it honest against the code. The
//! spec's `` ### `verb` `` headings must list exactly the verbs in
//! [`streamsim::server::proto::VERBS`], in the same order — add a
//! verb without documenting it (or document one that doesn't exist)
//! and this fails.

use streamsim::server::proto::{Request, MIN_PROTO_VERSION,
                               PROTO_VERSION, VERBS};

const SPEC: &str = include_str!("../../docs/PROTOCOL.md");

/// The verb headings, in document order.
fn documented_verbs() -> Vec<String> {
    SPEC.lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("### `")?;
            let (verb, tail) = rest.split_once('`')?;
            tail.is_empty().then(|| verb.to_string())
        })
        .collect()
}

#[test]
fn spec_headings_match_the_verb_list_exactly() {
    assert_eq!(documented_verbs(), VERBS.to_vec(),
               "docs/PROTOCOL.md verb headings drifted from \
                proto::VERBS");
}

#[test]
fn every_verb_heading_is_parseable_as_a_verb() {
    // the parser's error message enumerates nothing, so probe it:
    // a bare line with only the verb must at least be *recognized*
    // (it may still want more fields — that's a different error
    // than "unknown verb")
    for verb in VERBS {
        let line = format!("{{\"verb\":\"{verb}\"}}");
        if let Err(msg) = Request::parse(&line) {
            assert!(!msg.contains("unknown verb"),
                    "verb {verb} from VERBS not recognized: {msg}");
        }
    }
}

#[test]
fn spec_states_the_current_versions() {
    assert!(
        SPEC.contains(&format!("protocol v{PROTO_VERSION}")),
        "spec header must state the current protocol version");
    assert!(
        SPEC.contains(&format!(
            "`{MIN_PROTO_VERSION} ..= {PROTO_VERSION}`",
        )) || SPEC
            .contains(&format!("`{MIN_PROTO_VERSION}..={PROTO_VERSION}`")),
        "spec must state the accepted hello version range");
    let schema = u64::from(streamsim::api::SCHEMA_VERSION);
    assert!(
        SPEC.contains(&format!("schema v{schema}")),
        "spec header must state the current schema version");
}
