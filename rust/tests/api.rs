//! Integration suite for the `streamsim::api` facade: CLI↔builder
//! equivalence, live snapshot-at-kernel-exit byte-identity, the
//! versioned schema contract (key-set golden + PR-1 compatibility),
//! and batch execution.

use streamsim::api::{BatchRunner, SimBuilder, StatDomain, StatMode,
                     StatsQuery, SCHEMA_VERSION};
use streamsim::api::{top_level_keys, workloads};
use streamsim::cli::{self, Command, RunArgs};

fn sv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

/// CLI-args → SimBuilder round trip, end to end: the document the CLI
/// writes for a flag set is byte-identical to the document the
/// equivalent facade session produces.
#[test]
fn cli_run_and_facade_session_produce_identical_documents() {
    let path = std::env::temp_dir().join("streamsim_api_roundtrip.json");
    let _ = std::fs::remove_file(&path);
    let argv = sv(&["run", "--bench", "l2_lat", "--preset", "minimal",
                    "--stat-mode", "tip", "--sim-threads", "1",
                    "-o", "l2_latency", "99",
                    "--stats-json", path.to_str().unwrap()]);
    let cmd = cli::parse(&argv).unwrap();
    cli::execute(cmd.clone()).unwrap();
    let cli_doc = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    let Command::Run(a) = cmd else { panic!() };
    let mut session = a.to_builder().build().unwrap();
    session.run_to_idle().unwrap();
    assert_eq!(session.snapshot().to_json(), cli_doc,
               "CLI and facade diverged for the same flags");
}

/// Typed-error mapping at the CLI boundary: the same bad inputs that
/// used to produce stringly errors now round-trip through ApiError.
#[test]
fn api_error_variants_surface_through_cli_execute() {
    let run = |preset: &str, bench: &str| {
        cli::execute(Command::Run(RunArgs {
            bench: Some(bench.into()),
            preset: preset.into(),
            ..RunArgs::default()
        }))
    };
    let e = run("nope", "l2_lat").unwrap_err().to_string();
    assert!(e.starts_with("unknown preset 'nope'"), "{e}");
    assert!(e.contains("have:"), "candidate list lost: {e}");
    let e = run("minimal", "nope").unwrap_err().to_string();
    assert!(e.starts_with("unknown benchmark 'nope'"), "{e}");
    assert!(e.contains("have:"), "candidate list lost: {e}");
}

/// The acceptance check: a Snapshot taken live, mid-run, at a kernel
/// exit byte-matches the exit print the full run records for that
/// same kernel-exit point — for both tip and exact modes.
#[test]
fn live_snapshot_at_kernel_exit_matches_final_exit_print() {
    for mode in [StatMode::PerStream, StatMode::AggregateExact] {
        let g = workloads::generate("bench1_mini").unwrap();
        let mut session = SimBuilder::preset("sm7_titanv_mini")
            .stat_mode(mode)
            .workload(g.workload.clone())
            .build()
            .unwrap();
        session.run_until_kernels_done(1).unwrap();
        assert!(!session.idle(), "mid-run by construction");
        let live = session.snapshot();
        assert!(live.kernels_done() >= 1);

        // re-render the exit block of every kernel that has exited by
        // the snapshot point (uid assignment is enqueue order,
        // 1-based — GPGPU-Sim convention — so the exited kernel's
        // trace is kernels[uid-1])
        let rendered: Vec<String> = live
            .kernel_times()
            .finished()
            .iter()
            .map(|(stream, uid, _)| {
                let name =
                    &g.workload.kernels[(*uid - 1) as usize].name;
                live.render_kernel_exit(name, *stream, *uid)
            })
            .collect();

        // run to completion; the first n recorded exit-log entries
        // were printed at exactly the point the live snapshot captured
        session.run_to_idle().unwrap();
        let fin = session.snapshot();
        assert!(fin.kernels_done() > live.kernels_done(),
                "the live snapshot must be a true mid-run copy");
        let mut expected: Vec<&String> =
            fin.exit_log()[..rendered.len()].iter().collect();
        for r in &rendered {
            let pos = expected
                .iter()
                .position(|e| *e == r)
                .unwrap_or_else(|| panic!(
                    "mode {}: live snapshot render diverged from the \
                     recorded exit print:\n{r}", mode.label()));
            expected.remove(pos);
        }
    }
}

/// A snapshot taken at idle serializes byte-identically to a fresh
/// end-of-run snapshot (snapshotting never perturbs state), and the
/// pinned-window (`_pw`) views of a mid-run snapshot reflect only the
/// still-open windows.
#[test]
fn snapshots_are_pure_reads() {
    let mut session = SimBuilder::preset("minimal")
        .bench("l2_lat")
        .build()
        .unwrap();
    session.run_until_kernels_done(2).unwrap();
    let mid = session.snapshot();
    // taking more snapshots changes nothing
    assert_eq!(session.snapshot().to_json(), mid.to_json());
    // per-window counters for exited kernels' streams were cleared at
    // exit; the cumulative view keeps them
    let pw = mid.count(&StatsQuery::new().domain(StatDomain::L2)
        .pinned_window());
    let cum = mid.count(&StatsQuery::new().domain(StatDomain::L2));
    assert!(cum > pw, "cumulative {cum} vs pw {pw}");
    session.run_to_idle().unwrap();
    let fin1 = session.snapshot().to_json();
    let fin2 = session.snapshot().to_json();
    assert_eq!(fin1, fin2);
}

/// Schema contract: the versioned document's top-level key set (and
/// the version itself) match the committed golden
/// (`tests/golden/schema_v2_keys.txt`). Any drift must bump
/// SCHEMA_VERSION and rebless — see tests/golden/README.md.
#[test]
fn schema_key_set_matches_committed_golden() {
    let mut session = SimBuilder::preset("minimal")
        .bench("l2_lat")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let doc = session.snapshot().to_json();
    let mut got = vec![format!("schema_version={SCHEMA_VERSION}")];
    got.extend(top_level_keys(&doc));
    let got = got.join("\n") + "\n";

    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/schema_v2_keys.txt");
    let want = std::fs::read_to_string(&path).unwrap_or_else(|_| {
        panic!("missing committed golden {}", path.display())
    });
    assert_eq!(got, want,
               "result-document schema drifted: bump SCHEMA_VERSION \
                and rebless tests/golden/schema_v2_keys.txt only for \
                an intended change");
}

/// PR-1 compatibility shim: the old document shape still serializes,
/// without version fields, and is embedded verbatim in the v2 body.
#[test]
fn pr1_document_shape_still_available() {
    let mut session = SimBuilder::preset("minimal")
        .bench("l2_lat")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let snap = session.snapshot();
    let pr1 = snap.to_pr1_json();
    assert_eq!(
        top_level_keys(&pr1),
        ["config", "total_cycles", "kernels_done", "l1", "l2",
         "kernels", "dram_per_stream", "icnt_per_stream",
         "power_per_stream_fj", "dropped_responses"]
            .map(String::from),
        "PR-1 compatibility shape changed");
    let body = pr1.strip_prefix('{').unwrap()
        .strip_suffix('}').unwrap();
    assert!(snap.to_json().contains(body),
            "v2 document no longer embeds the PR-1 body");
}

/// BatchRunner end-to-end: a mixed scenario batch across the worker
/// pool equals the same scenarios run one by one.
#[test]
fn batch_runner_matches_individual_sessions() {
    let scenarios = [("l2_lat", StatMode::PerStream),
                     ("bench1_mini", StatMode::PerStream),
                     ("l2_lat", StatMode::AggregateExact)];
    let jobs: Vec<SimBuilder> = scenarios
        .iter()
        .map(|(bench, mode)| {
            SimBuilder::preset("minimal")
                .stat_mode(*mode)
                .sim_threads(1)
                .bench(bench)
        })
        .collect();
    let batch = BatchRunner::new(3).run(jobs.clone());
    assert_eq!(batch.len(), scenarios.len());
    for (job, result) in jobs.into_iter().zip(&batch) {
        let mut solo = job.build().unwrap();
        solo.run_to_idle().unwrap();
        assert_eq!(solo.snapshot().to_json(),
                   result.as_ref().unwrap().to_json());
    }
}
