//! FIG3 — paper Figure 3: `benchmark_1_stream.cu` (N = 1<<20, 256
//! threads/block; saxpy -> scale || saxpy -> add across 2 streams).
mod common;

fn main() {
    let bench = if std::env::var("STREAMSIM_BENCH_FAST").as_deref()
        == Ok("1") { "bench1_mini" } else { "bench1" };
    common::run_figure("Figure 3: benchmark_1_stream", bench,
                       "sm7_titanv_mini");
}
