//! ABL-1 — the cost of the paper's feature itself: per-stream stat
//! tracking on the increment hot path.
//!
//! The paper's change turns `vector<vector<u64>>` into
//! `map<streamID, vector<vector<u64>>>`; the question a maintainer
//! asks is "what does that cost per `inc_stats` call?". The engine
//! answers with interned dense slots: stream ids are interned once and
//! every increment afterwards is array indexing. This bench compares:
//!
//! * the engine by stat mode (flat exact / flat buggy / per-stream),
//!   driven through `inc(stream_id, ...)` (memo + binary search);
//! * the slot-indexed fast path the simulator actually uses
//!   (`inc_slot`), where interning happened once up front;
//! * a `BTreeMap<StreamId, table>` strawman — the structure the seed
//!   used for its DRAM/interconnect counters.

use std::collections::BTreeMap;

use streamsim::cache::access::{AccessOutcome, AccessType};
use streamsim::stats::{StatDomain, StatMode, StatsEngine};
use streamsim::util::bench::Bencher;
use streamsim::util::prng::SplitMix64;
use streamsim::StreamSlot;

const N: usize = 1_000_000;

/// Pre-generated event mix (4 streams, realistic type/outcome skew).
fn events(nstreams: u64) -> Vec<(AccessType, AccessOutcome, u64, u64)> {
    let mut rng = SplitMix64::new(0xAB1);
    (0..N)
        .map(|i| {
            let t = if rng.chance(0.7) {
                AccessType::GlobalAccR
            } else {
                AccessType::GlobalAccW
            };
            let o = match rng.next_below(10) {
                0..=5 => AccessOutcome::Hit,
                6..=7 => AccessOutcome::Miss,
                8 => AccessOutcome::MshrHit,
                _ => AccessOutcome::SectorMiss,
            };
            (t, o, rng.next_below(nstreams), i as u64 / 4)
        })
        .collect()
}

fn run_mode(evts: &[(AccessType, AccessOutcome, u64, u64)],
            mode: StatMode) -> u64 {
    let mut e = StatsEngine::new(mode);
    for (t, o, stream, cycle) in evts {
        e.inc(StatDomain::L2, *stream, *t, *o, *cycle);
    }
    std::hint::black_box(
        e.cache(StatDomain::L2).total_table().total());
    evts.len() as u64
}

/// The simulator's actual hot path: slots interned once, increments are
/// array indexing.
fn run_slot_indexed(evts: &[(AccessType, AccessOutcome, StreamSlot, u64)])
    -> u64 {
    let mut e = StatsEngine::new(StatMode::PerStream);
    for s in 0..64u64 {
        e.intern_stream(s);
    }
    for (t, o, slot, cycle) in evts {
        e.inc_slot(StatDomain::L2, *slot, *t, *o, *cycle);
    }
    std::hint::black_box(
        e.cache(StatDomain::L2).total_table().total());
    evts.len() as u64
}

/// The seed's DRAM/icnt structure: a `BTreeMap` entry per increment.
fn run_btreemap_strawman(evts: &[(AccessType, AccessOutcome, u64, u64)])
    -> u64 {
    let mut m: BTreeMap<u64, u64> = BTreeMap::new();
    for (_, _, stream, _) in evts {
        *m.entry(*stream).or_default() += 1;
    }
    std::hint::black_box(m.values().sum::<u64>());
    evts.len() as u64
}

fn main() {
    let evts = events(4);
    let mut b = Bencher::from_env();
    b.bench("flat_aggregate_exact (pre-patch ideal)", || {
        run_mode(&evts, StatMode::AggregateExact)
    });
    b.bench("flat_aggregate_buggy (clean + guard)", || {
        run_mode(&evts, StatMode::AggregateBuggy)
    });
    b.bench("per_stream_by_id (intern memo + search)", || {
        run_mode(&evts, StatMode::PerStream)
    });
    // many-streams stress: 64 streams instead of 4 — the alternating
    // pattern defeats any single-entry memo, which is exactly where
    // interned slots pay off
    let evts64 = events(64);
    b.bench("per_stream_by_id_64_streams", || {
        run_mode(&evts64, StatMode::PerStream)
    });
    let evts64_slots: Vec<_> = evts64
        .iter()
        .map(|(t, o, s, c)| (*t, *o, *s as StreamSlot, *c))
        .collect();
    b.bench("per_stream_slot_indexed_64_streams", || {
        run_slot_indexed(&evts64_slots)
    });
    b.bench("btreemap_strawman_64_streams (seed dram/icnt)", || {
        run_btreemap_strawman(&evts64)
    });
    b.report("ABL-1: stat-increment hot path (items = inc_stats calls)");

    let flat = b.results()[0].median;
    let tip = b.results()[2].median;
    let by_id64 = b.results()[3].median;
    let slot64 = b.results()[4].median;
    // like-for-like ratios: tip-vs-flat on the 4-stream mix, and the
    // interning win (slot-indexed vs by-id) on the 64-stream mix
    println!("\nper-stream overhead vs flat (4 streams): {:.2}x",
             tip.as_secs_f64() / flat.as_secs_f64());
    println!("slot-indexed speedup vs by-id (64 streams): {:.2}x",
             by_id64.as_secs_f64() / slot64.as_secs_f64());

    // perf-trajectory recorder: `scripts/ci.sh bench` merges this into
    // BENCH_stats.json next to the perf_sim_throughput sections
    if let Ok(path) = std::env::var("STREAMSIM_BENCH_JSON") {
        let doc = format!(
            "{{\"bench\":\"abl_stats_overhead\",\"sections\":{{\
             \"abl1\":{}}}}}",
            b.results_json());
        match std::fs::write(&path, doc) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}
