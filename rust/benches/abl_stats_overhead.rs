//! ABL-1 — the cost of the paper's feature itself: per-stream stat
//! containers vs the flat baseline on the increment hot path, plus the
//! batched Pallas/PJRT aggregation alternative.
//!
//! The paper's change turns `vector<vector<u64>>` into
//! `map<streamID, vector<vector<u64>>>`; the question a maintainer
//! asks is "what does that cost per `inc_stats` call?".

use streamsim::cache::access::{AccessOutcome, AccessType};
use streamsim::stats::{CacheStats, StatMode};
use streamsim::util::bench::Bencher;
use streamsim::util::prng::SplitMix64;

const N: usize = 1_000_000;

/// Pre-generated event mix (4 streams, realistic type/outcome skew).
fn events() -> Vec<(AccessType, AccessOutcome, u64, u64)> {
    let mut rng = SplitMix64::new(0xAB1);
    (0..N)
        .map(|i| {
            let t = if rng.chance(0.7) {
                AccessType::GlobalAccR
            } else {
                AccessType::GlobalAccW
            };
            let o = match rng.next_below(10) {
                0..=5 => AccessOutcome::Hit,
                6..=7 => AccessOutcome::Miss,
                8 => AccessOutcome::MshrHit,
                _ => AccessOutcome::SectorMiss,
            };
            (t, o, rng.next_below(4), i as u64 / 4)
        })
        .collect()
}

fn run_mode(evts: &[(AccessType, AccessOutcome, u64, u64)],
            mode: StatMode) -> u64 {
    let mut s = CacheStats::new(mode);
    for (t, o, stream, cycle) in evts {
        s.inc(*t, *o, *stream, *cycle);
    }
    std::hint::black_box(s.total_table().total());
    evts.len() as u64
}

fn main() {
    let evts = events();
    let mut b = Bencher::from_env();
    b.bench("flat_aggregate_exact (pre-patch ideal)", || {
        run_mode(&evts, StatMode::AggregateExact)
    });
    b.bench("flat_aggregate_buggy (clean + guard)", || {
        run_mode(&evts, StatMode::AggregateBuggy)
    });
    b.bench("per_stream_map (the paper's tip)", || {
        run_mode(&evts, StatMode::PerStream)
    });
    // many-streams stress: 64 streams instead of 4
    let mut rng = SplitMix64::new(7);
    let evts64: Vec<_> = evts
        .iter()
        .map(|(t, o, _, c)| (*t, *o, rng.next_below(64), *c))
        .collect();
    b.bench("per_stream_map_64_streams", || {
        run_mode(&evts64, StatMode::PerStream)
    });
    b.report("ABL-1: stat-increment hot path (items = inc_stats calls)");

    let flat = b.results()[0].median;
    let tip = b.results()[2].median;
    println!("\nper-stream overhead vs flat: {:.2}x",
             tip.as_secs_f64() / flat.as_secs_f64());
}
