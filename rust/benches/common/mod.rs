//! Shared bench driver: run a paper figure's three-config comparison,
//! print the figure table + validation verdicts + timing, so each
//! `cargo bench` target regenerates one table/figure of the paper.

use streamsim::config::SimConfig;
use streamsim::harness::{all_passed, render_checks, run_three_configs};
use streamsim::util::bench::{fmt_duration, Bencher};
use streamsim::workloads;

/// Regenerate one figure: simulate the three configs (timed), print the
/// comparison table, the check verdicts, and throughput.
pub fn run_figure(title: &str, bench: &str, preset: &str) {
    println!("\n######## {title} ########");
    let g = workloads::generate(bench).expect("workload");
    let cfg = SimConfig::preset(preset).expect("preset");
    println!("workload {}: {} kernels, {} mem instrs, streams {:?}",
             g.name, g.workload.kernels.len(),
             g.workload.mem_instr_count(), g.workload.streams());

    let mut b = Bencher::from_env();
    // timed: the tip (patched, concurrent) run — the paper's feature
    let mut last = None;
    b.bench("tip_concurrent_run", || {
        let tw = run_three_configs(&cfg, &g).expect("three-way");
        let accesses = tw.tip.stats.total_accesses();
        last = Some(tw);
        accesses
    });
    let tw = last.unwrap();
    b.report(&format!("{title} — simulation wall time (all 4 configs)"));

    println!("\n{}", tw.figure(title).render_table());
    let checks = tw.validate(&g);
    println!("checks:\n{}", render_checks(&checks));
    println!("tip cycles: {} | serialized cycles: {} | speedup from \
              concurrency: {:.2}x",
             tw.tip.stats.total_cycles(),
             tw.tip_serialized.stats.total_cycles(),
             tw.tip_serialized.stats.total_cycles() as f64
                 / tw.tip.stats.total_cycles() as f64);
    println!("clean dropped increments: L1={} L2={}",
             tw.clean.stats.l1().dropped(),
             tw.clean.stats.l2().dropped());
    let ok = all_passed(&checks);
    println!("figure validation: {}",
             if ok { "PASS" } else { "FAIL" });
    assert!(ok, "{title} failed validation");
    let _ = fmt_duration; // re-export warmers for targets that want it
}
