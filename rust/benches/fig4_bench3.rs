//! FIG4 — paper Figure 4: `benchmark_3_stream.cu` (N = 1<<18, 1024
//! threads/block).
mod common;

fn main() {
    common::run_figure("Figure 4: benchmark_3_stream", "bench3",
                       "sm7_titanv_mini");
}
