//! ABL-2 — magnitude of the clean-mode under-count as concurrency
//! grows: sweep the number of parallel streams running identical
//! kernels and report how many increments the flat counter loses
//! (paper §1/Fig. 1's inaccuracy, quantified).

use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::stats::StatMode;
use streamsim::util::bench::Bencher;
use streamsim::workloads::l2_lat;
use streamsim::workloads::stream_bench;

fn run(bench_workload: &streamsim::trace::Workload, mode: StatMode)
    -> (u64, u64) {
    let mut cfg = SimConfig::preset("sm7_titanv_mini").unwrap();
    cfg.stat_mode = mode;
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(bench_workload).unwrap();
    sim.run().unwrap();
    let total = sim.stats().l1().total_table().total()
        + sim.stats().l2().total_table().total();
    let dropped =
        sim.stats().l1().dropped() + sim.stats().l2().dropped();
    (total, dropped)
}

fn main() {
    println!("\n== ABL-2: clean-mode under-count vs stream count ==");
    println!("{:<10} {:>14} {:>14} {:>12} {:>10}",
             "streams", "exact_total", "clean_total", "lost", "lost%");
    let mut b = Bencher::from_env();
    for nstreams in [1u32, 2, 4, 8] {
        let p = l2_lat::Params {
            num_streams: nstreams,
            iters: 64,
            array_size: 16,
            ..l2_lat::Params::default()
        };
        let g = l2_lat::generate(&p);
        let (exact, _) = run(&g.workload, StatMode::AggregateExact);
        let (clean, dropped) = run(&g.workload,
                                   StatMode::AggregateBuggy);
        println!("{:<10} {:>14} {:>14} {:>12} {:>9.2}%",
                 nstreams, exact, clean, dropped,
                 100.0 * dropped as f64 / exact.max(1) as f64);
        assert_eq!(exact - clean, dropped);
        b.bench(&format!("l2_lat_{nstreams}streams_sim"), || {
            run(&g.workload, StatMode::PerStream).0
        });
    }

    // the Figs. 3-4 style workload
    let g = stream_bench::generate(&stream_bench::Params::mini());
    let (exact, _) = run(&g.workload, StatMode::AggregateExact);
    let (clean, dropped) = run(&g.workload, StatMode::AggregateBuggy);
    println!("{:<10} {:>14} {:>14} {:>12} {:>9.2}%",
             "bench1m", exact, clean, dropped,
             100.0 * dropped as f64 / exact.max(1) as f64);

    b.report("ABL-2: simulation time per stream count (items = stat \
              increments)");
}
