//! FIG5 — paper Figure 5: DeepBench `inference_half_35_1500_2560_0_0`
//! as a 2-stream tiled-GEMM trace, plus the functional GEMM through the
//! AOT Pallas artifact when `artifacts/` is built.
mod common;

use streamsim::functional;
use streamsim::runtime::{default_artifact_dir, Runtime};

fn main() {
    let bench = if std::env::var("STREAMSIM_BENCH_FAST").as_deref()
        == Ok("1") { "deepbench_mini" } else { "deepbench" };
    common::run_figure("Figure 5: DeepBench inference_half_35_1500_2560",
                       bench, "sm7_titanv_mini");

    // functional half: the same GEMM, numerically, through PJRT
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("\n(skipping functional GEMM: run `make artifacts`)");
        return;
    }
    let mut rt = Runtime::new().expect("PJRT");
    rt.load_dir(&dir).expect("artifacts");
    let mut b = streamsim::util::bench::Bencher::from_env();
    b.bench("pallas_gemm_35x2560x1500_fp16", || {
        let r = functional::check_gemm(&rt, "deepbench_gemm", 35, 2560,
                                       1500).expect("gemm");
        assert!(r.passed);
        (35 * 2560 * 1500) as u64 // MACs per run
    });
    b.report("Figure 5 — functional GEMM (PJRT CPU, interpret-mode \
              Pallas artifact; items = MACs)");
}
