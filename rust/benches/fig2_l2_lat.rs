//! FIG2 — paper Figure 2: `l2_lat_4stream` under tip / clean /
//! tip_serialized. Regenerates the per-stream cache-stat bars and the
//! timeline panels.
mod common;

fn main() {
    common::run_figure("Figure 2: l2_lat_4stream (4 streams, shared \
                        pointer-chase array)", "l2_lat", "minimal");
}
