//! PERF-L3 — end-to-end simulator throughput: simulated cycles/s and
//! cache accesses/s on the paper's workloads, across presets and stat
//! modes. This is the §Perf baseline/tracking bench for EXPERIMENTS.md.
//!
//! Set `STREAMSIM_BENCH_JSON=<path>` to also write the results as a
//! JSON document — `scripts/ci.sh` uses this to record the perf
//! trajectory in `BENCH_stats.json` at the repo root.

use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::stats::StatMode;
use streamsim::util::bench::Bencher;
use streamsim::workloads;

fn sim_once(bench: &str, preset: &str, mode: StatMode) -> (u64, u64) {
    sim_once_threaded(bench, preset, mode, 1)
}

fn sim_once_threaded(bench: &str, preset: &str, mode: StatMode,
                     threads: u32) -> (u64, u64) {
    sim_once_exchange(bench, preset, mode, threads, true)
}

fn sim_once_exchange(bench: &str, preset: &str, mode: StatMode,
                     threads: u32, sharded: bool) -> (u64, u64) {
    sim_once_idle(bench, preset, mode, threads, sharded, true)
}

fn sim_once_idle(bench: &str, preset: &str, mode: StatMode,
                 threads: u32, sharded: bool, idle_skip: bool)
    -> (u64, u64) {
    sim_once_ff(bench, preset, mode, threads, sharded, idle_skip,
                true)
}

fn sim_once_ff(bench: &str, preset: &str, mode: StatMode,
               threads: u32, sharded: bool, idle_skip: bool,
               fast_forward: bool) -> (u64, u64) {
    let g = workloads::generate(bench).unwrap();
    let mut cfg = SimConfig::preset(preset).unwrap();
    cfg.stat_mode = mode;
    cfg.sim_threads = threads;
    cfg.icnt_sharded = sharded;
    cfg.idle_skip = idle_skip;
    cfg.fast_forward = fast_forward;
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    (sim.stats().total_cycles, sim.stats().total_accesses())
}

fn write_json(sections: &[(&str, &Bencher)]) {
    let Ok(path) = std::env::var("STREAMSIM_BENCH_JSON") else {
        return;
    };
    let mut doc = String::from(
        "{\"bench\":\"perf_sim_throughput\",\"sections\":{");
    for (i, (name, b)) in sections.iter().enumerate() {
        if i > 0 {
            doc.push(',');
        }
        doc.push_str(&format!("\"{name}\":{}", b.results_json()));
    }
    doc.push_str("}}");
    match std::fs::write(&path, doc) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let fast = std::env::var("STREAMSIM_BENCH_FAST").as_deref()
        == Ok("1");
    let bench1 = if fast { "bench1_mini" } else { "bench1" };
    let deepb = if fast { "deepbench_mini" } else { "deepbench" };

    let mut b = Bencher::from_env();
    // throughput in simulated cycles/s
    for (bench, preset) in [
        (bench1, "sm7_titanv_mini"),
        ("bench3", "sm7_titanv_mini"),
        (deepb, "sm7_titanv_mini"),
        ("l2_lat", "minimal"),
    ] {
        b.bench(&format!("{bench}/{preset} cycles"), || {
            sim_once(bench, preset, StatMode::PerStream).0
        });
    }
    b.report("PERF-L3: simulated cycles/s (items = GPU cycles)");

    let mut b2 = Bencher::from_env();
    for mode in [StatMode::PerStream, StatMode::AggregateExact,
                 StatMode::AggregateBuggy] {
        b2.bench(&format!("{bench1} accesses ({})", mode.label()), || {
            sim_once(bench1, "sm7_titanv_mini", mode).1
        });
    }
    b2.report("PERF-L3: cache accesses/s by stat mode (items = \
               accesses)");

    // the full TITAN V geometry (80 SMs) on bench3
    let mut b3 = Bencher::new(1, 3);
    b3.bench("bench3/sm7_titanv (80 SMs) cycles", || {
        sim_once("bench3", "sm7_titanv", StatMode::PerStream).0
    });
    b3.report("PERF-L3: full TITAN V preset");

    // seq vs parallel sharded loop (same workload, same stats —
    // determinism suite guarantees bit-identity; this records the
    // wall-clock win). 80-SM preset so 4 workers have real work.
    let mut b4 = Bencher::from_env();
    for threads in [1u32, 2, 4] {
        b4.bench(&format!("bench3/sm7_titanv sim-threads={threads}"),
                 || {
            sim_once_threaded("bench3", "sm7_titanv",
                              StatMode::PerStream, threads).0
        });
    }
    b4.report("PERF-L3: seq vs parallel core/partition loop (items = \
               GPU cycles)");

    // the tentpole before/after: central (PR-2) vs sharded exchange,
    // same commit, same workload, byte-identical stats (determinism
    // suite) — only the wall clock differs. The 1-thread sharded
    // case must stay within noise of 1-thread central.
    let mut b5 = Bencher::from_env();
    for &(sharded, label) in
        &[(false, "central"), (true, "sharded")]
    {
        for threads in [1u32, 2, 4, 8] {
            b5.bench(&format!(
                "bench3/sm7_titanv t={threads} {label}"), || {
                sim_once_exchange("bench3", "sm7_titanv",
                                  StatMode::PerStream, threads,
                                  sharded).0
            });
        }
    }
    b5.report("PERF-L3: central vs sharded icnt exchange (items = \
               GPU cycles)");

    // the PR-6 before/after: always-tick (idle_skip=0) vs the
    // idle-aware active set (idle_skip=1, the default). Same stats
    // byte for byte (determinism suite); only the wall clock moves.
    // idle_tail is the adversarial scenario — one serialized
    // straggler keeps the GPU >95% idle for most of the run.
    let idle_tail = if fast { "idle_tail_mini" } else { "idle_tail" };
    let mut b6 = Bencher::from_env();
    for &(skip, label) in &[(false, "off"), (true, "on")] {
        for bench in [bench1, "bench3", idle_tail] {
            for threads in [1u32, 4, 8] {
                b6.bench(&format!(
                    "{bench}/sm7_titanv t={threads} idle_skip={label}"),
                    || {
                    sim_once_idle(bench, "sm7_titanv",
                                  StatMode::PerStream, threads, true,
                                  skip).0
                });
            }
        }
    }
    b6.report("PERF-L3: always-tick vs idle-aware active set (items = \
               GPU cycles)");

    // the PR-9 before/after: always-tick (fast_forward=0) vs
    // event-horizon clock jumps (fast_forward=1, the default). Same
    // stats byte for byte (determinism suite); only the wall clock
    // moves. idle_tail is again the adversarial scenario — its
    // straggler tail is one long provably-quiet stretch the jump
    // loop crosses in a handful of iterations.
    let mut b7 = Bencher::from_env();
    for &(ff, label) in &[(false, "off"), (true, "on")] {
        for bench in [bench1, "bench3", idle_tail] {
            for threads in [1u32, 4, 8] {
                b7.bench(&format!(
                    "{bench}/sm7_titanv t={threads} \
                     fast_forward={label}"),
                    || {
                    sim_once_ff(bench, "sm7_titanv",
                                StatMode::PerStream, threads, true,
                                true, ff).0
                });
            }
        }
    }
    b7.report("PERF-L3: always-tick vs event-horizon fast-forward \
               (items = GPU cycles)");

    write_json(&[("cycles", &b), ("accesses_by_mode", &b2),
                 ("titanv_full", &b3), ("parallel", &b4),
                 ("sharded_icnt", &b5), ("idle_skip", &b6),
                 ("fast_forward", &b7)]);
}
