//! Minimal offline stand-in for the [`anyhow`] crate.
//!
//! The build environment has no network access, so the real crate
//! cannot be fetched from a registry. This shim implements exactly the
//! subset `streamsim` uses, with matching semantics:
//!
//! * [`Error`] — a boxed-free error carrying its context/cause chain as
//!   strings. `{}` prints the outermost message, `{:#}` the whole chain
//!   joined by `": "`, and `{:?}` the anyhow-style "Caused by:" report.
//! * [`Result<T>`] — alias with [`Error`] as the default error type.
//! * `?` conversions from any `std::error::Error` (the source chain is
//!   captured eagerly).
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std errors and [`Error`]) and on `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// The error type: an outermost-first chain of messages.
pub struct Error {
    /// `chain[0]` is the most recent context; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `Context` adds).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> String {
        self.chain[0].clone()
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the full chain, as anyhow does
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` — attach a message to the
/// failure path of a `Result` or the `None` of an `Option`.
pub trait Context<T> {
    /// Attach a context message eagerly.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a context message lazily (only on failure).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: `",
                                  stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn context_layers_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r
            .context("opening trace")
            .context("loading workload")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading workload");
        assert_eq!(format!("{e:#}"),
                   "loading workload: opening trace: missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert_eq!(e.root_cause(), "missing");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32> = Ok(7);
        let v = ok
            .with_context(|| {
                called = true;
                "never"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("--bench is required").unwrap_err();
        assert_eq!(e.to_string(), "--bench is required");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("{} {}", "a", 1).to_string(), "a 1");
        let n = 5;
        assert_eq!(anyhow!("captured {n}").to_string(), "captured 5");
        assert_eq!(anyhow!(io_err()).to_string(), "missing");
    }
}
