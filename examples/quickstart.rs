//! Quickstart: simulate the paper's 4-stream L2 microbenchmark with
//! per-stream stats and print the breakdown the paper's §4 describes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::stats::print as stat_print;
use streamsim::workloads;

fn main() -> anyhow::Result<()> {
    // 1. Pick a config preset (the paper validates on a TITAN V) and
    //    make sure concurrent kernels + per-stream stats are on —
    //    paper §4 step 1: `-gpgpu_concurrent_kernel_sm 1`.
    let mut cfg = SimConfig::preset("sm7_titanv_mini")?;
    cfg.concurrent_kernel_sm = true;
    cfg.stat_mode = streamsim::stats::StatMode::PerStream;
    println!("config: {}\n", cfg.summary());

    // 2. Generate the paper's §5.1 workload: 4 streams running the
    //    same pointer-chase kernel over one shared array.
    let g = workloads::generate("l2_lat")?;
    println!("workload: {} ({} kernels on streams {:?})\n",
             g.name, g.workload.kernels.len(), g.workload.streams());

    // 3. Simulate.
    let mut sim = GpuSim::new(cfg)?;
    sim.enqueue_workload(&g.workload)?;
    sim.run()?;
    let stats = sim.stats();
    println!("simulated {} cycles, {} kernels retired\n",
             stats.total_cycles, stats.kernels_done);

    // 4. Per-stream breakdowns — the paper's headline output
    //    ("L2_cache_stats_breakdown", §4 step 4).
    print!("{}", stat_print::print_all_streams(
        stats.l2(), "L2_cache_stats_breakdown"));

    // 5. Per-kernel launch/exit windows (§3.2) + the timeline.
    for (stream, uid, _) in stats.kernel_times.finished() {
        print!("{}", stat_print::print_kernel_time(
            &stats.kernel_times, stream, uid));
    }
    println!("\n{}", sim.render_timeline(72));
    Ok(())
}
