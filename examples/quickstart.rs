//! Quickstart: simulate the paper's 4-stream L2 microbenchmark with
//! per-stream stats through the `streamsim::api` facade — build a
//! session, run it, snapshot it, ask typed per-stream questions.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use streamsim::api::{SimBuilder, StatDomain, StatMode, StatsQuery};

fn main() -> anyhow::Result<()> {
    // 1. Build the session. The builder layers preset → knobs →
    //    workload and validates everything once; a typo here comes
    //    back as a typed ApiError, not a stringly chain. The paper
    //    validates on a TITAN V with concurrent kernels + per-stream
    //    stats on (§4 step 1: `-gpgpu_concurrent_kernel_sm 1`).
    let mut session = SimBuilder::preset("sm7_titanv_mini")
        .stat_mode(StatMode::PerStream) // the paper's `tip`
        .bench("l2_lat") // §5.1: 4 streams, one shared pointer-chase
        .build()?;
    println!("config: {}\n", session.config().summary());

    // 2. Run. Sessions are resumable — `step()` /
    //    `run_until_kernels_done(n)` let you stop anywhere and
    //    snapshot mid-run; here we just drain the queue.
    session.run_to_idle()?;

    // 3. Snapshot: a deep copy of every statistic at this cycle.
    //    Snapshots work exactly the same mid-run (live, between
    //    steps) and at exit.
    let snap = session.snapshot();
    println!("simulated {} cycles, {} kernels retired\n",
             snap.total_cycles(), snap.kernels_done());

    // 4. Per-stream breakdowns — the paper's headline output
    //    ("L2_cache_stats_breakdown", §4 step 4), as typed queries
    //    instead of scraped prints.
    for (stream, total) in snap.per_stream(StatDomain::L2) {
        println!("stream {stream}: {total} L2 stat increments");
    }
    let reads = StatsQuery::new()
        .domain(StatDomain::L2)
        .access_type(streamsim::api::AccessType::GlobalAccR);
    for row in snap.rows(&reads) {
        println!("  L2[{}][{}] stream {} = {}",
                 row.access_type.unwrap().name(),
                 row.outcome.unwrap().name(), row.stream, row.count);
    }

    // 5. Per-kernel launch/exit windows (§3.2) + the timeline.
    for (stream, uid, w) in snap.kernel_times().finished() {
        println!("kernel uid {uid} on stream {stream}: cycles \
                  {}..{}", w.start_cycle, w.end_cycle);
    }
    println!("\n{}", snap.render_timeline(72));

    // 6. The versioned machine-readable document (`schema_version`
    //    field; same serializer as the CLI's --stats-json).
    println!("{}", snap.to_json());
    Ok(())
}
