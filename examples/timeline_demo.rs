//! Figure 1 reproduction: two kernels on different streams overlap and
//! update the same stat cell in the same cycle — the clean (unpatched)
//! counter under-counts, the per-stream (tip) counters don't. Driven
//! through the `streamsim::api` facade; the trace data model is
//! re-exported there for hand-built workloads.
//!
//! ```bash
//! cargo run --release --example timeline_demo
//! ```

use streamsim::api::trace::{Dim3, KernelTrace, MemInstr, MemSpace,
                            TbTrace, TraceOp, Workload};
use streamsim::api::{SimBuilder, StatMode};

/// Two identical kernels on two streams, disjoint footprints, enough
/// parallel warps that both cores bump `GLOBAL_ACC_R/MISS` in the same
/// cycle.
fn workload() -> Workload {
    let mk = |stream: u64, base: u64| KernelTrace {
        name: format!("overlap_k{stream}"),
        kernel_id: 1,
        grid: Dim3::linear(8),
        block: Dim3::linear(64),
        stream_id: stream,
        shared_mem_bytes: 0,
        tbs: (0..8)
            .map(|tb| TbTrace {
                warps: (0..2)
                    .map(|w| {
                        vec![TraceOp::Mem(MemInstr {
                            pc: 0,
                            space: MemSpace::Global,
                            is_write: false,
                            size: 4,
                            base_addr: base
                                + (tb * 2 + w) as u64 * 0x80,
                            stride: 4,
                            active_mask: u32::MAX,
                            l1_bypass: false,
                        })]
                    })
                    .collect(),
            })
            .collect(),
    };
    Workload {
        kernels: vec![mk(1, 0x10_0000), mk(2, 0x80_0000)],
        memcpys: vec![],
    }
}

fn run(mode: StatMode) -> (u64, u64, String) {
    let mut session = SimBuilder::preset("sm7_titanv_mini")
        .stat_mode(mode)
        .workload(workload())
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let snap = session.snapshot();
    let total = snap.l1().total_table().total()
        + snap.l2().total_table().total();
    let dropped = snap.losses().guard_dropped_total();
    (total, dropped, snap.render_timeline(72))
}

fn main() {
    println!("=== Figure 1: overlapping kernels and the stat \
              under-count ===\n");
    let (tip_total, _, gantt) = run(StatMode::PerStream);
    let (clean_total, dropped, _) = run(StatMode::AggregateBuggy);
    let (exact_total, _, _) = run(StatMode::AggregateExact);

    println!("timeline (concurrent, per-stream tracking):\n{gantt}");
    println!("total stat increments:");
    println!("  tip (per-stream, patched):   {tip_total}");
    println!("  exact oracle:                {exact_total}");
    println!("  clean (unpatched, flat):     {clean_total}   \
              <- lost {dropped} same-cycle cross-stream increments");
    assert_eq!(tip_total, exact_total);
    assert!(clean_total <= exact_total);
    if dropped > 0 {
        println!("\nThe unpatched counter under-counted by {} — the \
                  inaccuracy the paper's Figure 1 illustrates.",
                 exact_total - clean_total);
    }
}
