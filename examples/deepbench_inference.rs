//! End-to-end driver (DESIGN.md §6): DeepBench
//! `inference_half_35_1500_2560_0_0` through the *whole* stack —
//!
//! 1. generate the multi-stream tiled-GEMM trace (L3 workload gen);
//! 2. run the timing simulation in the paper's three configs and print
//!    per-stream stats + timelines (the paper's Fig. 5) — all through
//!    the `streamsim::api` facade (snapshot views only);
//! 3. execute the *functional* GEMM through the AOT-compiled Pallas
//!    artifact on the PJRT CPU client (L1/L2 via the Rust runtime) and
//!    check the numerics against a host oracle;
//! 4. batch-aggregate the simulator's own stat events through the
//!    Pallas `stats_aggregate` artifact and cross-check.
//!
//! ```bash
//! make artifacts && cargo run --release --example deepbench_inference
//! ```

use streamsim::api::{all_passed, render_checks, run_three_configs,
                     workloads, AccessOutcome, AccessType, SimConfig,
                     StatDomain};
use streamsim::functional;
use streamsim::runtime::{default_artifact_dir, HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    // ---- 1+2: timing simulation, three configs ------------------------
    let g = workloads::generate("deepbench")?;
    println!("workload: {} — {} kernels on streams {:?}",
             g.name, g.workload.kernels.len(), g.workload.streams());
    println!("memory instructions: {}\n",
             g.workload.mem_instr_count());

    let cfg = SimConfig::preset("sm7_titanv_mini")?;
    let tw = run_three_configs(&cfg, &g)?;
    println!("{}", tw.figure("Figure 5: DeepBench inference_half_35_\
                              1500_2560_0_0").render_table());
    let checks = tw.validate(&g);
    println!("checks:\n{}", render_checks(&checks));
    anyhow::ensure!(all_passed(&checks), "timing validation failed");

    // throughput numbers for EXPERIMENTS.md
    let cycles = tw.tip.stats.total_cycles();
    let accesses = tw.tip.stats.total_accesses();
    println!("tip run: {cycles} cycles, {accesses} cache accesses\n");

    // ---- 3: functional GEMM through the Pallas artifact ---------------
    let dir = default_artifact_dir();
    anyhow::ensure!(dir.join("manifest.txt").exists(),
                    "run `make artifacts` first");
    let mut rt = Runtime::new()?;
    rt.load_dir(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    let r = functional::check_gemm(&rt, "deepbench_gemm", 35, 2560,
                                   1500)?;
    println!("functional GEMM 35x2560x1500 fp16: [{}] max_err={:.3e} \
              checksum={:.3}",
             if r.passed { "PASS" } else { "FAIL" }, r.max_abs_err,
             r.checksum);
    anyhow::ensure!(r.passed, "functional GEMM failed");

    // ---- 4: stat aggregation through the Pallas artifact --------------
    // replay the tip run's L2 stat cube as an event batch; the artifact
    // takes fixed 16384-event batches, so deterministically downsample
    // each cell by a common stride (the batched-aggregation deployment
    // would simply loop over batches)
    let snap = &tw.tip.stats;
    let l2_streams = snap.l2().streams();
    let n = 16384usize;
    let grand_total: u64 = l2_streams
        .iter()
        .map(|s| {
            snap.dense_rows(StatDomain::L2, *s)
                .iter()
                .flatten()
                .sum::<u64>()
        })
        .sum();
    let stride = grand_total.div_ceil(n as u64).max(1);
    let (mut sid, mut typ, mut outc, mut valid) =
        (vec![0i32; n], vec![0i32; n], vec![0i32; n], vec![0i32; n]);
    let mut i = 0;
    let mut expected_cells = Vec::new();
    for s in &l2_streams {
        for (t, row) in snap.dense_rows(StatDomain::L2, *s)
            .iter()
            .enumerate()
        {
            for (o, count) in row.iter().enumerate() {
                let sampled = count / stride;
                expected_cells.push((*s, t, o, sampled));
                for _ in 0..sampled {
                    sid[i] = *s as i32;
                    typ[i] = t as i32;
                    outc[i] = o as i32;
                    valid[i] = 1;
                    i += 1;
                }
            }
        }
    }
    let mk = |v: &[i32]| HostTensor::I32 { data: v.to_vec(),
                                           dims: vec![n] };
    let out = rt.execute("stats_aggregate",
                         &[mk(&sid), mk(&typ), mk(&outc), mk(&valid)])?;
    let cube0 = out[0].as_f32();
    let total: f32 = cube0.iter().sum();
    println!("Pallas stats_aggregate: {total} events binned \
              ({grand_total} total, 1/{stride} sample)");
    anyhow::ensure!(total as usize == i, "aggregation count mismatch");
    // exact per-cell agreement at the sampled scale
    for (s, t, o, want) in expected_cells {
        let got = cube0[(s as usize * AccessType::COUNT + t)
                        * AccessOutcome::COUNT + o];
        anyhow::ensure!(got as u64 == want,
                        "cell s={s} t={t} o={o}: {got} != {want}");
    }

    // per-stream read totals agree between simulator and MXU kernel
    let cube = out[0].as_f32();
    for s in l2_streams.into_iter().filter(|s| *s < 8) {
        let kernel_reads: f32 = (0..AccessOutcome::COUNT)
            .map(|o| cube[(s as usize * AccessType::COUNT
                           + AccessType::GlobalAccR.idx())
                          * AccessOutcome::COUNT + o])
            .sum();
        println!("  stream {s}: GLOBAL_ACC_R total via Pallas cube = \
                  {kernel_reads}");
    }

    println!("\nEND-TO-END OK");
    Ok(())
}
