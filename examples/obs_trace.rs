//! Observability tour: record a run with `obs_enabled`, export the
//! Chrome `trace_event` document (load it at <https://ui.perfetto.dev>
//! or `chrome://tracing`), rebuild the per-stream Gantt chart from
//! the very same event stream, and print one interval-metrics
//! exposition — all through the `streamsim::api` facade.
//!
//! ```bash
//! cargo run --release --example obs_trace > trace.json
//! ```
//!
//! The CLI equivalent is `streamsim run --bench l2_lat --trace-out
//! trace.json --metrics-interval 500`; over the wire it is the
//! `trace` and `metrics` verbs (see docs/PROTOCOL.md).

use streamsim::api::{SimBuilder, StatMode};
use streamsim::obs::trace::kernel_spans;
use streamsim::timeline;

fn main() -> anyhow::Result<()> {
    let mut session = SimBuilder::preset("sm7_titanv_mini")
        .stat_mode(StatMode::PerStream)
        .obs_enabled(true) // off by default; recording is opt-in
        .bench("l2_lat")
        .build()?;

    // sample a mid-run interval the way --metrics-interval does
    let before = session.snapshot();
    session.run_to_idle()?;
    let after = session.snapshot();
    let diff = after.diff(&before)?;
    eprintln!("{}", streamsim::obs::metrics::render_interval(
        after.total_cycles(), &diff));

    // the recorded kernel spans are the gpu_kernel_time windows
    for (stream, uid, name, start, end) in
        kernel_spans(session.events())
    {
        eprintln!("stream {stream} kernel {uid} ({name}): \
                   cycles {start}..{end}");
    }

    // the event stream alone is enough to redraw the timeline
    let tracker = timeline::tracker_from_events(session.events());
    eprintln!("{}", timeline::render_gantt(&tracker, 72));

    // stdout gets the Perfetto-loadable document
    println!("{}", session.trace_json());
    Ok(())
}
