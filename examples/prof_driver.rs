//! Profiling driver for the §Perf pass: one full-size `bench3` run on
//! the 80-SM TITAN V preset (see EXPERIMENTS.md §Perf), driven
//! through the `streamsim::api` facade.
//!
//! ```bash
//! cargo build --release --example prof_driver
//! perf record -g target/release/examples/prof_driver
//! ```
use streamsim::api::SimBuilder;

fn main() {
    let mut session = SimBuilder::preset("sm7_titanv")
        .bench("bench3")
        .build()
        .unwrap();
    session.run_to_idle().unwrap();
    let snap = session.snapshot();
    println!("cycles={} accesses={}", snap.total_cycles(),
             snap.total_accesses());
}
