//! Profiling driver for the §Perf pass: one full-size `bench3` run on
//! the 80-SM TITAN V preset (see EXPERIMENTS.md §Perf).
//!
//! ```bash
//! cargo build --release --example prof_driver
//! perf record -g target/release/examples/prof_driver
//! ```
use streamsim::config::SimConfig;
use streamsim::sim::GpuSim;
use streamsim::workloads;

fn main() {
    let g = workloads::generate("bench3").unwrap();
    let cfg = SimConfig::preset("sm7_titanv").unwrap();
    let mut sim = GpuSim::new(cfg).unwrap();
    sim.enqueue_workload(&g.workload).unwrap();
    sim.run().unwrap();
    println!("cycles={} accesses={}", sim.stats().total_cycles,
             sim.stats().total_accesses());
}
