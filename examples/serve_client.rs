//! Serve-client tour: drive the `streamsim::server` wire protocol
//! end to end — hello, submit/wait, a memoized resubmission,
//! streaming per-stream stat deltas, and a graceful shutdown.
//!
//! Self-contained: the example spins up a [`SimServer`] on an
//! ephemeral loopback port in a background thread and then talks to
//! it exactly the way an external client would — one JSON request
//! per line, one JSON response frame per line. Swap the in-process
//! server for `streamsim serve --port 7878` and the client half of
//! this file works unchanged.
//!
//! ```bash
//! cargo run --release --example serve_client
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;

use streamsim::server::proto::{JobSpec, Request, Response,
                               PROTO_VERSION};
use streamsim::server::{ServerConfig, SimServer};

/// One blocking request/response exchange over the line protocol.
fn call(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream,
        req: &Request) -> anyhow::Result<Response> {
    writeln!(writer, "{}", req.to_json())?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Response::parse(line.trim_end()).map_err(anyhow::Error::msg)
}

fn main() -> anyhow::Result<()> {
    // 1. A server, as `streamsim serve --port 0` would start one:
    //    two workers, bounded lanes, result memoization on.
    let server =
        SimServer::bind("127.0.0.1:0", ServerConfig::default())?;
    let addr = server.local_addr()?;
    let server = thread::spawn(move || server.serve());
    println!("server listening on {addr}\n");

    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;

    // 2. Version handshake. The server refuses mismatched
    //    `proto_version`s with a typed error + goodbye rather than
    //    misinterpreting frames.
    let hello = call(&mut reader, &mut writer, &Request::Hello {
        proto_version: PROTO_VERSION,
    })?;
    println!("handshake: {}", hello.to_json());

    // 3. Submit the paper's 4-stream L2 microbenchmark and block on
    //    the result. The reply's `doc` is byte-identical to what a
    //    direct in-process `SimSession` run would serialize.
    let spec = JobSpec::bench("l2_lat");
    let Response::Submitted { job_id, .. } =
        call(&mut reader, &mut writer,
             &Request::Submit { spec: spec.clone() })?
    else {
        anyhow::bail!("submit was refused");
    };
    let Response::JobDone { doc, memo_hit, .. } =
        call(&mut reader, &mut writer, &Request::Wait { job_id })?
    else {
        anyhow::bail!("job {job_id} failed");
    };
    println!("job {job_id}: {} bytes of stats JSON \
              (memo_hit={memo_hit})", doc.len());

    // 4. Resubmit the identical spec: the server recognises the
    //    resolved config + workload pair and replays the stored
    //    document without re-simulating.
    let Response::Submitted { job_id, memo_hit } =
        call(&mut reader, &mut writer,
             &Request::Submit { spec })?
    else {
        anyhow::bail!("resubmit was refused");
    };
    let warm =
        call(&mut reader, &mut writer, &Request::Wait { job_id })?;
    let Response::JobDone { doc: warm_doc, .. } = warm else {
        anyhow::bail!("memo replay failed");
    };
    println!("job {job_id}: memo_hit={memo_hit}, replay is \
              byte-identical: {}\n", warm_doc == doc);

    // 5. Stream a fresh run: `Delta` frames every 64 cycles carrying
    //    only the per-stream counters that changed, then the final
    //    document — the wire form of mid-run snapshots.
    writeln!(writer, "{}", Request::Stream {
        spec: JobSpec::bench("l2_lat"),
        interval: 64,
    }.to_json())?;
    writer.flush()?;
    let mut line = String::new();
    loop {
        line.clear();
        reader.read_line(&mut line)?;
        match Response::parse(line.trim_end())
            .map_err(anyhow::Error::msg)?
        {
            Response::Delta { seq, cycles, domains, .. } => {
                let cells: usize =
                    domains.iter().map(|(_, c)| c.len()).sum();
                println!("delta #{seq} @ cycle {cycles}: \
                          {cells} per-stream cells changed");
            }
            Response::JobDone { job_id, .. } => {
                println!("stream job {job_id} finished\n");
                break;
            }
            other => anyhow::bail!("unexpected frame {other:?}"),
        }
    }

    // 6. Graceful shutdown: the server stops accepting, finishes
    //    in-flight work, says goodbye on every connection, and
    //    `serve()` returns the final versioned stats document with
    //    the `server` and `service` sections.
    let bye = call(&mut reader, &mut writer, &Request::Shutdown)?;
    println!("shutdown: {}", bye.to_json());
    let final_doc = server.join().expect("server thread")?;
    println!("final stats document:\n{final_doc}");
    Ok(())
}
