//! Full §5 validation drive: runs the paper's three configurations
//! (`tip`, `clean`, `tip_serialized`) on the Figs. 2–4 benchmarks,
//! prints the figure tables and check verdicts — the `graph.py`
//! replacement. Everything runs through the `streamsim::api` facade
//! (the three-way harness is re-exported there and reads snapshots
//! only).
//!
//! ```bash
//! cargo run --release --example multi_stream_validation
//! ```

use streamsim::api::{all_passed, render_checks, run_three_configs,
                     workloads, SimConfig};

fn main() -> anyhow::Result<()> {
    let figures = [
        ("Figure 2: l2_lat_4stream", "l2_lat", "minimal"),
        ("Figure 3: benchmark_1_stream (mini)", "bench1_mini",
         "sm7_titanv_mini"),
        ("Figure 4: benchmark_3_stream", "bench3", "sm7_titanv_mini"),
    ];
    let mut failures = 0;
    for (title, bench, preset) in figures {
        let g = workloads::generate(bench)?;
        let cfg = SimConfig::preset(preset)?;
        let tw = run_three_configs(&cfg, &g)?;
        println!("{}", tw.figure(title).render_table());
        let checks = tw.validate(&g);
        println!("checks:\n{}", render_checks(&checks));
        if !all_passed(&checks) {
            failures += 1;
        }
        // the paper's green-vs-orange observation, summarized —
        // losses come from the one unified report
        let tip = tw.tip.stats.l2().total_table().total()
            + tw.tip.stats.l1().total_table().total();
        let clean = tw.clean.stats.l2().total_table().total()
            + tw.clean.stats.l1().total_table().total();
        let lost = tw.clean.stats.losses().guard_dropped_total();
        println!("tip total = {tip}, clean total = {clean} \
                  (clean lost {lost} increments)\n{}\n",
                 "=".repeat(72));
    }
    anyhow::ensure!(failures == 0, "{failures} figure(s) failed");
    println!("ALL FIGURES VALIDATED");
    Ok(())
}
